"""Sharded checkpointing: atomic, async, keep-k, elastic re-shard on restore.

Format: one directory per step (``step_00000042/``) holding ``manifest.json``
(tree paths, shapes, dtypes) + one ``.npy`` per leaf.  Writes go to a
``.tmp`` dir first and are renamed into place (atomic wrt. crashes); an
async mode runs serialization off the training thread (device_get is the
only synchronous part).  ``restore`` accepts any mesh/shardings — restoring
onto a different mesh IS the elastic-scaling path (the arrays are re-sharded
by device_put).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _paths_of(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [v for _, v in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()                       # one in-flight save at a time
        keys, leaves, _ = _paths_of(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "extra": extra or {}, "leaves": []}
            for i, (k, arr) in enumerate(zip(keys, host)):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
                manifest["leaves"].append(
                    {"key": k, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """template: pytree (arrays or SDS) defining structure; shardings:
        optional matching tree of Shardings (elastic re-shard)."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        keys, leaves, treedef = _paths_of(template)
        assert keys == [l["key"] for l in manifest["leaves"]], \
            "checkpoint/template tree mismatch"
        arrs = [np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
                for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
        else:
            arrs = [jax.numpy.asarray(a) for a in arrs]
        return jax.tree_util.tree_unflatten(treedef, arrs), manifest["extra"]
