"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run as a fresh process: the first two lines force 512
placeholder host devices before jax initializes.

Per cell:
  1. FULL model, scan-over-layers, lower+compile on the requested mesh
     -> proves the distribution config (sharding, collectives, memory).
  2. (--roofline, single-pod only) 1-period and 2-period *unrolled* variants
     -> cost_analysis of each; linear extrapolation in layer periods gives
     whole-model HLO FLOPs / bytes / collective bytes (XLA's cost analysis
     counts while bodies once — measured, see EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import cells, get_config, get_shape          # noqa: E402
from repro.core import roofline as RL                           # noqa: E402
from repro.launch import sharding as SH                         # noqa: E402
from repro.launch.mesh import data_shards, make_production_mesh # noqa: E402
from repro.models import RuntimeConfig, build_model             # noqa: E402
from repro.models import modules as M                           # noqa: E402
from repro.models import transformer as T                       # noqa: E402
from repro.optim import OptConfig                               # noqa: E402
from repro.serve.step import make_serve_step                    # noqa: E402
from repro.train.step import make_train_step                    # noqa: E402


def runtime_for(mesh, shape, scan_layers=True, overrides=None):
    rt = RuntimeConfig(
        remat="dots" if shape.kind == "train" else "none",
        moe_groups=data_shards(mesh),
        # production serving default: int8 KV (§Perf A4 — validated to
        # 0.03 max logit error; halves the decode memory floor)
        cache_dtype="int8" if shape.kind == "decode" else "bfloat16",
        scan_layers=scan_layers)
    if overrides:
        rt = dataclasses.replace(rt, **overrides)
    return rt


def reduced_period_cfg(cfg, k: int):
    """cfg with first_dense + k periods of the main group (for extrapolation)."""
    groups = T.plan_groups(cfg)
    main = groups[-1]
    P = len(main.pattern)
    L = cfg.first_dense_layers + k * P
    changes = {"num_layers": L}
    if cfg.encoder_decoder:
        changes["num_encoder_layers"] = k
    return dataclasses.replace(cfg, **changes), groups[-1].repeats


def lower_cell(cfg, shape, mesh, rt, rules=None):
    """Build + lower + compile one cell. Returns (compiled, seconds)."""
    from repro.core import partitioning as PT
    from repro.models.registry import input_specs
    model = build_model(cfg, rt)
    if rules is None:
        rules = SH.TRAIN_RULES if shape.kind != "decode" else SH.DECODE_RULES
        if shape.kind == "decode" and shape.global_batch == 1:
            rules = SH.wide_tp_rules(SH.DECODE_RULES)

    boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pdtype = jnp.float32 if shape.kind == "train" else jnp.dtype(cfg.dtype)
    boxed = jax.tree.map(
        lambda p: M.Param(jax.ShapeDtypeStruct(p.value.shape, pdtype), p.axes),
        boxed, is_leaf=M.is_param)
    params_sds = SH.sds_with_sharding(boxed, mesh, rules)

    bspec = SH.batch_spec(mesh, rules)
    specs = input_specs(cfg, shape, rt)

    def shard_batch(b):
        from repro.core.partitioning import mesh_size
        bsz = mesh_size(bspec[0], mesh) if len(bspec) else 1

        def one(v):
            spec = bspec if (v.ndim and bsz > 1 and v.shape[0] % bsz == 0) \
                else jax.sharding.PartitionSpec()
            return jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=jax.sharding.NamedSharding(mesh, spec))
        return {k: one(v) for k, v in b.items()}

    t0 = time.time()
    ctx = PT.activation_rules(mesh, rules)
    if shape.kind == "train":
        step_fn, opt = make_train_step(build_model(cfg, rt), OptConfig())
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_sds = opt_sds._replace(
            mu=_like(opt_sds.mu, params_sds, mesh, rules, boxed),
            nu=_like(opt_sds.nu, params_sds, mesh, rules, boxed))
        with mesh, ctx:
            lowered = jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, shard_batch(specs["batch"]))
    elif shape.kind == "prefill":
        model = build_model(cfg, rt)

        def prefill_fn(params, batch):
            logits, caches = model.prefill(params, batch)
            return jnp.argmax(logits[:, -1:, :], -1), caches
        with mesh, ctx:
            lowered = jax.jit(prefill_fn).lower(
                params_sds, shard_batch(specs["batch"]))
    else:
        serve_fn = make_serve_step(build_model(cfg, rt))
        cache_sh = SH.cache_sharding(specs["caches"], mesh, rules,
                                     shape.global_batch)
        caches_sds = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            specs["caches"], cache_sh)
        with mesh, ctx:
            lowered = jax.jit(serve_fn, donate_argnums=(2,)).lower(
                params_sds, shard_batch(specs["batch"]), caches_sds)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, {"lower_s": t_lower, "compile_s": t_compile}


def _like(tree, params_sds, mesh, rules, boxed):
    """Give optimizer-moment SDS the same shardings as their params."""
    shard = SH.shardings_for_tree(boxed, mesh, rules)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shard)


def analyze(compiled):
    out = {}
    try:
        ca = RL.cost_analysis(compiled)
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes"] = float(ca.get("bytes accessed", 0.0))
        out["transcendentals"] = float(ca.get("transcendentals", 0.0))
    except Exception as e:   # pragma: no cover
        out["cost_error"] = str(e)
    try:
        txt = compiled.as_text()
        st = RL.parse_collectives(txt)
        out["collectives"] = {"bytes": st.bytes_by_kind,
                              "counts": st.count_by_kind,
                              "link_bytes": st.link_bytes}
        out["convert_bytes"] = RL.convert_bytes(txt)
        if "bytes" in out:
            # floor at 20%: the adjustment (x1.5 in+out estimate) may
            # overshoot on convert-heavy programs
            out["bytes_adj"] = max(out["bytes"] - out["convert_bytes"],
                                   0.2 * out["bytes"])
        out["hlo_chars"] = len(txt)
    except Exception as e:   # pragma: no cover
        out["hlo_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out.setdefault("memory", {})[k] = int(v)
    except Exception as e:
        out["memory_error"] = str(e)
    return out


def run_cell(arch, shape_name, mesh_kind, *, do_roofline=True, overrides=None):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "status": "ok"}
    try:
        rt = runtime_for(mesh, shape, overrides=overrides)
        compiled, times = lower_cell(cfg, shape, mesh, rt)
        rec["times"] = times
        rec["full"] = analyze(compiled)
        del compiled
        if do_roofline and mesh_kind == "single":
            per = {}
            for k in (1, 2):
                cfg_k, repeats = reduced_period_cfg(cfg, k)
                rt_k = runtime_for(mesh, shape, scan_layers=False,
                                   overrides=overrides)
                compiled_k, _ = lower_cell(cfg_k, shape, mesh, rt_k)
                per[k] = analyze(compiled_k)
                per[k]["repeats_full"] = repeats
                del compiled_k
            rec["periods"] = per
            rec["roofline"] = extrapolate(per, cfg, shape, mesh)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def extrapolate(per, cfg, shape, mesh):
    """cost(R) = cost(1p) + (R-1) * (cost(2p) - cost(1p))."""
    c1, c2 = per[1], per[2]
    R = c1["repeats_full"]
    out = {}
    for key in ("flops", "bytes", "bytes_adj"):
        if key in c1 and key in c2:
            out[key] = c1[key] + (R - 1) * (c2[key] - c1[key])
    cb1 = c1.get("collectives", {}).get("link_bytes", 0.0)
    cb2 = c2.get("collectives", {}).get("link_bytes", 0.0)
    out["collective_link_bytes"] = cb1 + (R - 1) * (cb2 - cb1)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    terms = RL.RooflineTerms(
        flops=out.get("flops", 0.0), bytes_accessed=out.get("bytes", 0.0),
        collective_link_bytes=out["collective_link_bytes"], chips=chips,
        model_flops=RL.model_flops_for(cfg, shape))
    out.update(terms.as_dict())
    if "bytes_adj" in out:
        out["t_memory_adj_s"] = out["bytes_adj"] / RL.HBM_BW
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-roofline", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        for arch, sname, skip in cells():
            for mesh_kind in ("single", "multi"):
                todo.append((arch, sname, mesh_kind))
    else:
        todo.append((args.arch, args.shape, args.mesh))

    for arch, sname, mesh_kind in todo:
        path = os.path.join(args.out, f"{arch}__{sname}__{mesh_kind}.json")
        if args.all and os.path.exists(path):
            continue
        t0 = time.time()
        rec = run_cell(arch, sname, mesh_kind,
                       do_roofline=not args.no_roofline)
        rec["wall_s"] = time.time() - t0
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        print(f"[{rec['status']:5s}] {arch} {sname} {mesh_kind} "
              f"({rec['wall_s']:.0f}s)", flush=True)
        if rec["status"] == "error":
            print(rec["error"], flush=True)


if __name__ == "__main__":
    main()
