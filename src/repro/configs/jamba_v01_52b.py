"""jamba-v0.1-52b — hybrid Mamba + attention (1:7) with MoE (16e top-2).

[arXiv:2403.19887; hf]  32L d_model=4096 32H kv=8 d_ff=14336 vocab=65536.
attn_layer_period=8 offset=4; expert_layer_period=2 offset=1.  No positional
embedding (Mamba provides position information).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

# period-8 mixer pattern: attention only at index 4 (1 attn : 7 mamba)
_PATTERN = tuple("attn" if i == 4 else "mamba" for i in range(8))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    pos_emb="none",
    block_pattern=_PATTERN,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, num_experts_per_tok=2, d_ff=14336,
                  norm_topk_prob=True),
    moe_period=2,
    moe_offset=1,
)
