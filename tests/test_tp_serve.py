"""Tensor-parallel serving tests (4 fake CPU devices via a subprocess).

The TP engine (``repro.dist.tp`` + ``ServingEngine(tp=N)``) must be
*token-identical* to single-device serving in exact mode: column-parallel
projections compute a bitwise column subset and ``gather_cols`` is a tiled
all-gather, so nothing reassociates.  Overlap mode (ring collective
matmuls) is tolerance-equal only and is tested against einsum references.

Heavy tests run inside ``run_with_devices`` subprocesses (the fake-device
XLA flag must be set before jax imports); plan/quantize validation runs
in-process.
"""
import numpy as np
import pytest

from test_dist import run_with_devices

# Shared preamble: tiny 2-layer attention arch (H=4, KV=4, hd=32) with a
# mixed-length trace whose last prompt shares a prefix with an earlier one
# (exercises radix reuse under chunked prefill).
PRELUDE = """
    import jax, numpy as np
    from repro.configs import get_config, reduced
    from repro.models import RuntimeConfig, build_model
    from repro.models import modules as M
    from repro.serve import EngineConfig
    from repro.serve.kvcache import PagedBackend
    from repro.serve.scheduler import Request, ServingEngine
    from repro.serve.step import make_prefill_step, make_serve_step

    PROMPTS = [np.arange(1, 4 + 7 * i) % 63 + 1 for i in range(4)]
    PROMPTS += [np.concatenate([PROMPTS[2][:12], np.asarray([9, 9, 9])])]

    def build(KV=4, moe=False, **rt_kw):
        name = "qwen2-moe-a2.7b" if moe else "qwen1.5-0.5b"
        cfg = reduced(get_config(name), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=128, num_heads=4,
                      num_kv_heads=KV, head_dim=32)
        model = build_model(cfg, RuntimeConfig(remat="none", **rt_kw))
        params = M.unbox(model.init(jax.random.PRNGKey(0)))
        return model, params

    def run(model, params, tp, backend=None, chunked=True,
            tp_mode="exact", tracer=None):
        be = backend if backend is not None else (
            PagedBackend(page_size=16) if chunked else "dense")
        eng = ServingEngine(
            model, prefill_step=make_prefill_step(model),
            serve_step=make_serve_step(model), params=params,
            backend=be, tracer=tracer,
            config=EngineConfig(
                slots=3, cache_len=64,
                backend=be if isinstance(be, str) else be.name,
                chunked_prefill=chunked, chunk_size=8,
                prefix_cache=chunked, tp=tp, tp_mode=tp_mode))
        reqs = [Request(rid=i, prompt=np.asarray(p, np.int32),
                        max_new_tokens=6)
                for i, p in enumerate(PROMPTS)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        assert all(r.done for r in reqs)
        return [r.out for r in reqs], eng
"""


def test_tp4_token_identity_bf16_and_telemetry():
    """tp=4 chunked+prefix == tp=1 bitwise; per-device streamed bytes are
    exactly 1/4; async dispatch overlaps and emits its span pair."""
    run_with_devices(PRELUDE + """
        from repro.obs import Tracer
        model, params = build()
        o1, e1 = run(model, params, 1)
        tr = Tracer()
        o4, e4 = run(model, params, 4, tracer=tr)
        assert o1 == o4, (o1, o4)
        m1, m4 = e1.metrics(), e4.metrics()
        assert m4["kv_shards"] == 4
        assert m4["kv_bytes_streamed"] == m1["kv_bytes_streamed"]
        assert m4["kv_bytes_streamed_per_device"] * 4 == \\
            m4["kv_bytes_streamed"]
        assert m4["dispatch_overlap_fraction"] > 0
        assert tr.events("device_submit") and tr.events("stream_out")
        # submit precedes the matching stream-out: spans interleave
        t_sub = tr.events("device_submit")[0][0]
        t_out = tr.events("stream_out")[0][0]
        assert t_sub <= t_out
        print("OK")
    """, n=4)


def test_tp4_token_identity_int8_kv():
    run_with_devices(PRELUDE + """
        model, params = build()
        be = lambda: PagedBackend(page_size=32, kv_dtype="int8")
        o1, e1 = run(model, params, 1, backend=be())
        o4, e4 = run(model, params, 4, backend=be())
        assert o1 == o4, (o1, o4)
        m1, m4 = e1.metrics(), e4.metrics()
        assert m4["kv_bytes_streamed"] == m1["kv_bytes_streamed"]
        assert m4["kv_bytes_streamed_per_device"] * 4 == \\
            m4["kv_bytes_streamed"]
        print("OK")
    """, n=4)


def test_tp4_gqa_fallback_and_bucketed_backends():
    """KV=2 < tp=4 replicates KV (kv_shards=1) yet stays token-identical;
    the non-chunked dense and paged bucketed paths shard too."""
    run_with_devices(PRELUDE + """
        model2, params2 = build(KV=2)
        o1, _ = run(model2, params2, 1)
        o4, e4 = run(model2, params2, 4)
        assert o1 == o4, (o1, o4)
        m4 = e4.metrics()
        assert m4["kv_shards"] == 1
        assert m4["kv_bytes_streamed_per_device"] == m4["kv_bytes_streamed"]

        model, params = build()
        o1, _ = run(model, params, 1, chunked=False)
        o4, _ = run(model, params, 4, chunked=False)
        assert o1 == o4, (o1, o4)
        o1, _ = run(model, params, 1,
                    backend=PagedBackend(page_size=16), chunked=False)
        o4, _ = run(model, params, 4,
                    backend=PagedBackend(page_size=16), chunked=False)
        assert o1 == o4, (o1, o4)
        print("OK")
    """, n=4)


def test_tp4_moe_expert_parallel_identity():
    run_with_devices(PRELUDE + """
        model, params = build(moe=True)
        o1, _ = run(model, params, 1)
        o4, _ = run(model, params, 4)
        assert o1 == o4, (o1, o4)
        print("OK")
    """, n=4)


def test_overlap_collectives_match_einsum():
    """Ring collective matmuls (3-D activations, incl. int8-quantized
    weights) match the plain einsum within fp32 tolerance, and the
    overlap-mode engine drains every request."""
    run_with_devices(PRELUDE + """
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.collective_matmul import (allgather_matmul,
                                                  reduce_scatter_matmul)
        from repro.quant.tensor import quantize, dequantize
        mesh = jax.make_mesh((4,), ("tp",))
        B, T, K, N = 2, 6, 64, 96
        x = jax.random.normal(jax.random.PRNGKey(0), (B, T, K), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
        ref = jnp.einsum("btk,kn->btn", x, w)
        ag = shard_map(lambda xl, wf: allgather_matmul(xl, wf, "tp"),
                       mesh=mesh, in_specs=(P(None, None, "tp"), P()),
                       out_specs=P(), check_rep=False)
        np.testing.assert_allclose(ag(x, w), ref, rtol=1e-4, atol=1e-4)
        rs = shard_map(lambda xl, wl: reduce_scatter_matmul(xl, wl, "tp"),
                       mesh=mesh,
                       in_specs=(P(None, None, "tp"), P("tp", None)),
                       out_specs=P(None, None, "tp"), check_rep=False)
        np.testing.assert_allclose(rs(x, w), ref, rtol=1e-4, atol=1e-4)
        # int8 weights: dequantized reference through the same ring
        qw = quantize(w, bits=8, group_size=32)
        wd = dequantize(qw).astype(jnp.float32)
        np.testing.assert_allclose(ag(x, wd),
                                   jnp.einsum("btk,kn->btn", x, wd),
                                   rtol=1e-4, atol=1e-4)

        model, params = build()
        oo, eo = run(model, params, 4, tp_mode="overlap")
        assert eo.metrics()["requests_finished"] == len(PROMPTS)
        print("OK")
    """, n=4)


def test_tp4_kv_page_bytes_invariant_mid_run():
    """sum(per-device resident page bytes) == logical resident bytes while
    requests are live (post-drain everything is freed and reads zero)."""
    run_with_devices(PRELUDE + """
        model, params = build()
        eng = ServingEngine(
            model, prefill_step=make_prefill_step(model),
            serve_step=make_serve_step(model), params=params,
            backend=PagedBackend(page_size=16),
            config=EngineConfig(slots=3, cache_len=64, backend="paged",
                                chunked_prefill=True, chunk_size=8,
                                prefix_cache=True, tp=4))
        for i, p in enumerate(PROMPTS):
            eng.submit(Request(rid=i, prompt=np.asarray(p, np.int32),
                               max_new_tokens=6))
        checked = 0
        for _ in range(200):
            if eng.step() is None and not eng.queue:
                break
            kb = eng.backend.kv_page_bytes()
            if kb["kv_page_bytes_resident"] > 0:
                per = kb["kv_page_bytes_per_device"]
                assert kb["kv_shards"] == 4 and len(per) == 4
                assert sum(per) == kb["kv_page_bytes_resident"]
                # never tp x the real footprint
                assert per[0] < kb["kv_page_bytes_logical"]
                checked += 1
        assert checked > 0
        print("OK")
    """, n=4)


# ---- plan / quantize validation ----------------------------------------

def test_plan_rejects_bad_configs():
    # the device-count check precedes the shape checks, so the shape
    # rejections also need the fake 4-device mesh
    run_with_devices(PRELUDE + """
        from repro.dist.tp import plan
        def raises(fn, frag):
            try:
                fn()
            except ValueError as e:
                assert frag in str(e), (frag, e)
            else:
                raise AssertionError(f"no error containing {frag!r}")
        model, _ = build()
        raises(lambda: plan(model, 2, mode="bogus"), "tp_mode")
        raises(lambda: plan(model, 1), "tp >= 2")
        raises(lambda: plan(model, 64), "devices visible")
        model3, _ = build(KV=3)
        raises(lambda: plan(model3, 2), "num_kv_heads")   # 2 % 3 != 0
        raises(lambda: plan(model3, 2, mode="overlap"), "num_kv_heads")
        model5, _ = build(paged_kernel_decode=True)
        raises(lambda: plan(model5, 2), "paged_kernel_decode")
        print("OK")
    """, n=4)


def _tiny_params(KV=4):
    import jax
    from repro.configs import get_config, reduced
    from repro.models import RuntimeConfig, build_model
    from repro.models import modules as M
    cfg = reduced(get_config("qwen1.5-0.5b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=128, num_heads=4, num_kv_heads=KV,
                  head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    return M.unbox(model.init(jax.random.PRNGKey(0)))


def test_quantize_tp_alignment():
    """Quantize-time shard contract: int4 row pairs and scale groups must
    not straddle the tensor-parallel shard boundary."""
    from repro.quant import quantize_params
    params = _tiny_params()
    with pytest.raises(AssertionError, match="int4"):
        quantize_params(params, bits=4, tp=2)
    with pytest.raises(AssertionError, match="scale groups"):
        # wo contraction extent 128 -> 32 rows per tp=4 shard, which
        # cannot hold a whole 64-row scale group
        quantize_params(params, bits=8, group_size=64, tp=4)
    q = quantize_params(params, bits=8, group_size=32, tp=4)
    assert q is not None
