"""Cycle-level model of the Spatz VPE — the paper-faithful reproduction.

Simulates the micro-architecture the paper describes, at beat granularity
(1 beat = F=4 64-bit elements = one cycle of unit throughput):

  * VRF: 4 banks, 3R/1W; the write-port arbitration is where the paper's
    structural conflicts live.  Register group g starts at bank (2g) mod 4
    (standard layout, §IV-D); beat b of group g hits bank (start+b) mod 4.
  * VFU: 1 beat/cycle, read->write latency LAT=3 (2-cycle FPU + writeback).
  * VLSU: total ``mem_beats_per_cycle`` beats/cycle of TCDM bandwidth.
      - Spatz_BASELINE: one interface, 1 beat/cycle.
      - Spatz_2xBW:     one *wide* interface, 2 consecutive beats/cycle
        (the 2-banks/cycle write stride of Fig. 2a).
      - TROOP:          two decoupled interfaces, each 1 beat/cycle on a
        contiguous half of every access (§IV-A).
  * Chaining: 1-bit credit (consumer sees the committed frontier with a
    1-cycle lag, single frontier) vs. TROOP per-interface completion
    counters — the consumer can use both halves' frontiers (§IV-B).
  * Write arbitration: static VFU>VLSU (baseline) vs. dynamic VLSU-first
    with a 2-entry shadow buffer absorbing VFU writes (§IV-C).
  * TCDM: for LMUL>=4 the two decoupled interfaces hit the same bank group
    unless address scrambling offsets the rows (§IV-E): modeled as the
    interfaces alternating (half throughput) when unscrambled.
  * Reductions: linear (1 element/cycle) vs. log2 steps x LAT (§IV-G).

FPU utilization = VFU busy beats / total cycles — the quantity of Fig. 5.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

F = 4                    # FPUs (64-bit lanes)
VRF_BANKS = 4
LAT = 3                  # VFU read->write latency
ELEMS_PER_INSTR = 64     # LMUL=8 x VLEN512 / 64-bit
BEATS_PER_INSTR = ELEMS_PER_INSTR // F      # 16


@dataclass(frozen=True)
class SpatzConfig:
    name: str
    mem_beats_per_cycle: int = 1
    decoupled: bool = False          # TROOP (A): two half-interfaces
    completion_chaining: bool = False  # TROOP (B)
    dynamic_priority: bool = False   # TROOP (C) + shadow buffer
    shadow_depth: int = 2
    scrambling: bool = False         # TROOP (E)
    log2_reduction: bool = False     # TROOP (G)
    issue_overhead: int = 2          # dispatch rate (cycles/instr, in order)
    # Scalar-core serialization per strip (address setup, loop control,
    # offload handshakes) — NOT itemized in the paper; calibrated ONCE on
    # DOTP@4096 against Fig. 5 and then held fixed for all other kernels
    # and vector lengths (see benchmarks/fig5_utilization.py for the
    # validation deltas).  TROOP's restructured software (unrolling /
    # software pipelining, §IV-F) hides it.
    sw_strip_overhead: int = 0


BASELINE = SpatzConfig("Spatz_BASELINE", mem_beats_per_cycle=1,
                       sw_strip_overhead=14)
BW2X = SpatzConfig("Spatz_2xBW", mem_beats_per_cycle=2,
                   sw_strip_overhead=6)
# NOTE shadow_depth=3: the paper's RTL arbiter absorbs the worst-case
# two-interface collision with 2 entries; our FIFO-drain discipline needs
# one more slot of slack to reach the same steady state (documented delta).
BW2X_TROOP = SpatzConfig(
    "Spatz_2xBW_TROOP", mem_beats_per_cycle=2, decoupled=True,
    completion_chaining=True, dynamic_priority=True, scrambling=True,
    log2_reduction=True, shadow_depth=3)
CONFIGS = {c.name: c for c in (BASELINE, BW2X, BW2X_TROOP)}


@dataclass
class Instr:
    kind: str                        # vle | vse | vfu | vred
    beats: int
    reg: int
    deps: List["Instr"] = field(default_factory=list)
    reads: List[int] = field(default_factory=list)   # source register ids
    war: List["Instr"] = field(default_factory=list) # prior readers of dst
    big_lmul: bool = True            # LMUL>=4 (TCDM conflict relevant)
    # state
    committed: int = 0               # total beats committed
    lo: int = 0                      # committed in [0, split)
    hi: int = 0                      # committed in [split, beats)
    issued: int = 0                  # VFU beats entered the pipe
    issued_lo: int = 0               # VLSU beats issued per half
    issued_hi: int = 0
    strip_leader: bool = False       # first memory op of a strip
    busy_frac: float = 1.0           # FPU-busy fraction (vred: slides idle)
    start: int = -1                  # dispatch-ready cycle
    done: bool = False

    @property
    def split(self) -> int:
        return (self.beats + 1) // 2

    def bank(self, b: int) -> int:
        return ((2 * self.reg) + b) % VRF_BANKS

    def frontier(self, completion: bool) -> int:
        """Usable leading-prefix beats for an in-order consumer."""
        if not completion:
            # single-frontier credit chaining: prefix only
            return self.lo if self.lo < self.split else self.split + self.hi
        if self.lo >= self.split:
            return self.split + self.hi
        return self.lo

    def commit(self, b: int):
        self.committed += 1
        if b < self.split:
            self.lo += 1
        else:
            self.hi += 1


@dataclass
class SimResult:
    cycles: int
    fpu_busy: int
    stalls: Dict[str, int]

    @property
    def fpu_util(self) -> float:
        return self.fpu_busy / max(self.cycles, 1)


class Spatz:
    """Two in-order units (VLSU, VFU) + bank-arbitrated VRF writes.

    TROOP adds: per-half completion chaining, a write buffer on the VLSU1
    path and a shadow buffer on the VFU path (paper §IV-B/C)."""

    def __init__(self, cfg: SpatzConfig):
        self.cfg = cfg

    @staticmethod
    def wire_hazards(program: List[Instr]):
        """Attach WAR dependencies: a write to reg r must not overtake
        earlier readers of r (per-beat, credit-style — paper §III-D)."""
        for i, ins in enumerate(program):
            ins.war = []
            for j in range(i - 1, -1, -1):
                prev = program[j]
                if prev.reg == ins.reg and prev.kind in ("vle", "vfu"):
                    break                      # previous writer: WAW barrier
                if ins.reg in prev.reads:
                    ins.war.append(prev)
        return program

    def run(self, program: List[Instr]) -> SimResult:
        cfg = self.cfg
        self.wire_hazards(program)
        dispatch_at = {id(ins): k * cfg.issue_overhead
                       for k, ins in enumerate(program)}
        mem_q = [i for i in program if i.kind in ("vle", "vse")]
        vfu_q = [i for i in program if i.kind in ("vfu", "vred")]
        mi = fi = 0                      # issue pointers
        cycle = 0
        fpu_busy = 0
        stalls = {"vrf": 0, "chain": 0, "tcdm": 0, "shadow": 0}
        pipe: List[Tuple[int, Instr, int]] = []      # VFU writebacks
        shadow: List[Tuple[Instr, int]] = []         # VFU write buffer (C)
        v1buf: List[Tuple[Instr, int]] = []          # VLSU1 write buffer (C)
        retry: List[Tuple[Instr, int, int]] = []     # static-priority losers
        remaining = len(program)

        def avail_ok(dep: Instr, b: int) -> bool:
            if cfg.completion_chaining:
                if b < dep.split:
                    return b < dep.lo
                return (b - dep.split) < dep.hi
            return b < dep.frontier(False)

        def war_ok(ins: Instr, b: int) -> bool:
            for rdr in ins.war:
                done_reads = rdr.issued if rdr.kind in ("vfu", "vred") \
                    else rdr.issued_lo + rdr.issued_hi
                if done_reads <= b and done_reads < rdr.beats:
                    return False
            return True

        for cycle in range(1, 2_000_000):
            if remaining == 0:
                break
            writes: List[Tuple[str, Instr, int]] = []

            # ----- VLSU issue ------------------------------------------------
            m = mem_q[mi] if mi < len(mem_q) else None
            if m is not None and cycle < dispatch_at[id(m)]:
                m = None
            if m is not None and m.strip_leader and m.start < 0:
                m.start = cycle + cfg.sw_strip_overhead
            if m is not None and m.start > 0 and cycle < m.start:
                m = None
            if retry:
                for (ri, rb, itf) in retry:
                    writes.append((f"vlsu{itf}", ri, rb))
                retry = []
            elif m is not None:
                if cfg.decoupled:
                    conflict = (not cfg.scrambling) and m.big_lmul
                    use_ifs = [cycle % 2] if conflict else [0, 1]
                    if conflict:
                        stalls["tcdm"] += 1
                    for itf in use_ifs:
                        if itf == 1 and len(v1buf) >= cfg.shadow_depth:
                            stalls["shadow"] += 1
                            continue
                        if itf == 0 and m.issued_lo < m.split:
                            b = m.issued_lo
                        elif itf == 1 and m.issued_hi < m.beats - m.split:
                            b = m.split + m.issued_hi
                        else:
                            continue
                        if m.kind == "vse":
                            if all(avail_ok(d, b) for d in m.deps):
                                writes.append((f"vse{itf}", m, b))
                            else:
                                stalls["chain"] += 1
                        elif war_ok(m, b):
                            writes.append((f"vlsu{itf}", m, b))
                        else:
                            stalls["chain"] += 1
                else:
                    for k in range(cfg.mem_beats_per_cycle):
                        b = m.issued_lo + m.issued_hi + k
                        if b >= m.beats:
                            break
                        if m.kind == "vse":
                            if all(avail_ok(d, b) for d in m.deps):
                                writes.append(("vse0", m, b))
                            else:
                                stalls["chain"] += 1
                                break
                        elif war_ok(m, b):
                            writes.append(("vlsu0", m, b))
                        else:
                            stalls["chain"] += 1
                            break
            # mark issued now (they either commit, buffer, or enter retry)
            for (src, inst, b) in writes:
                if src.startswith(("vlsu", "vse")):
                    if b < inst.split:
                        inst.issued_lo = max(inst.issued_lo, b + 1)
                    else:
                        inst.issued_hi = max(inst.issued_hi,
                                             b - inst.split + 1)
            if m is not None and m.issued_lo + m.issued_hi >= m.beats:
                mi += 1

            # ----- VFU issue -------------------------------------------------
            f = vfu_q[fi] if fi < len(vfu_q) else None
            if f is not None and cycle < dispatch_at[id(f)]:
                f = None
            if f is not None:
                b = f.issued
                if f.kind == "vred":
                    ready = all(d.committed >= d.beats for d in f.deps)
                else:
                    ready = all(avail_ok(d, b) for d in f.deps) and \
                        war_ok(f, b)
                if not ready:
                    stalls["chain"] += 1
                elif cfg.dynamic_priority and len(shadow) >= cfg.shadow_depth:
                    stalls["shadow"] += 1
                else:
                    f.issued += 1
                    fpu_busy += f.busy_frac
                    pipe.append((cycle + LAT, f, b))
                    if f.issued >= f.beats:
                        fi += 1            # pipelined back-to-back issue

            # ----- VRF write arbitration ------------------------------------
            due = [(inst, b) for (t, inst, b) in pipe if t <= cycle]
            pipe = [(t, inst, b) for (t, inst, b) in pipe if t > cycle]
            reqs: Dict[int, List[Tuple[str, Instr, int]]] = {}
            # buffers offer one entry per distinct bank (independent writes)
            offered = set()
            for qi, (inst, b) in enumerate(v1buf):
                bank = inst.bank(b)
                if bank not in offered:
                    offered.add(bank)
                    reqs.setdefault(bank, []).append(("v1buf", inst, b))
            offered = set()
            for qi, (inst, b) in enumerate(shadow):
                bank = inst.bank(b)
                if bank not in offered:
                    offered.add(bank)
                    reqs.setdefault(bank, []).append(("shadow", inst, b))
            for inst, b in due:
                reqs.setdefault(inst.bank(b), []).append(("vfu", inst, b))
            for (src, inst, b) in writes:
                if src.startswith("vse"):
                    inst.commit(b)           # memory-side write, no VRF port
                    if inst.committed == inst.beats:
                        remaining -= 1
                    continue
                reqs.setdefault(inst.bank(b), []).append((src, inst, b))

            if cfg.dynamic_priority:
                prio = {"vlsu0": 0, "v1buf": 1, "vlsu1": 2, "shadow": 3,
                        "vfu": 4}
            else:
                prio = {"vfu": 0, "shadow": 1, "vlsu0": 2, "v1buf": 3,
                        "vlsu1": 4}
            new_retry: List[Tuple[Instr, int, int]] = []
            for bank, cand in reqs.items():
                cand.sort(key=lambda r: prio[r[0]])
                src, inst, b = cand[0]
                if src == "shadow":
                    shadow.remove((inst, b))
                elif src == "v1buf":
                    v1buf.remove((inst, b))
                inst.commit(b)
                if inst.committed == inst.beats:
                    remaining -= 1
                if len(cand) > 1:
                    stalls["vrf"] += len(cand) - 1
                for src2, inst2, b2 in cand[1:]:
                    if src2 == "vfu":
                        if cfg.dynamic_priority and \
                                len(shadow) < cfg.shadow_depth:
                            shadow.append((inst2, b2))
                        else:
                            pipe.append((cycle + 1, inst2, b2))
                    elif src2 == "vlsu1" and cfg.dynamic_priority:
                        v1buf.append((inst2, b2))   # buffered, no stall
                    elif src2.startswith("vlsu"):
                        itf = int(src2[-1])
                        new_retry.append((inst2, b2, itf))
                        # interface stalls: roll the issue pointer back
                        if b2 < inst2.split:
                            inst2.issued_lo = min(inst2.issued_lo, b2 + 1)
                        else:
                            inst2.issued_hi = min(inst2.issued_hi,
                                                  b2 - inst2.split + 1)
                    # shadow/v1buf losers stay queued
            retry = new_retry

        return SimResult(cycle, fpu_busy, stalls)

# --------------------------------------------------------------------------
# Kernel micro-programs (strip-mined, LMUL=8)
# --------------------------------------------------------------------------
def _red_cost(cfg: SpatzConfig, elems: int = ELEMS_PER_INSTR):
    """(cycles, fpu_busy_fraction) of the final reduction.

    log2 (TROOP, §IV-G): ceil(log2(e)) slide+vfadd steps; each step costs a
    register-length slide on the SLDU, the add, and the LAT drain — ~300
    cycles for 64 elements, mostly FPU-idle (this is what caps the paper's
    DOTP at 76% for VL=4096 and is amortized to 96% at long VL).
    linear: element-serial accumulate through the FPU pipe.
    """
    import math
    if cfg.log2_reduction:
        steps = max(int(math.ceil(math.log2(elems))), 1)
        cycles = steps * (2 * BEATS_PER_INSTR + 2 * LAT + 12)
        busy = steps * BEATS_PER_INSTR / 2
    else:
        cycles = elems * LAT + 2 * BEATS_PER_INSTR
        busy = elems / 4
    return cycles, busy / max(cycles, 1)


def prog_dotp(vl: int, cfg: SpatzConfig) -> List[Instr]:
    """x.y: per strip 2 loads + 1 chained vfmacc.

    Baseline/2xBW: LMUL=8 — the VRF holds only FOUR register groups, so
    x/y/acc reuse is forced and the next strip's loads carry WAR hazards.
    TROOP: software-pipelined at LMUL=4 (§IV-F) — 8 register groups allow
    double-buffered x/y, removing the WAR chain entirely."""
    prog: List[Instr] = []
    acc_dep: Optional[Instr] = None
    if cfg.decoupled:
        beats = BEATS_PER_INSTR // 2
        strips = max(vl // (ELEMS_PER_INSTR // 2), 1)
        for s in range(strips):
            gx, gy = (0, 1) if s % 2 == 0 else (2, 3)
            lx = Instr("vle", beats, reg=gx, strip_leader=(s % 8 == 0))
            ly = Instr("vle", beats, reg=gy)
            fm = Instr("vfu", beats, reg=4, deps=[lx, ly],
                       reads=[gx, gy, 4])
            prog += [lx, ly, fm]
            acc_dep = fm
    else:
        strips = max(vl // ELEMS_PER_INSTR, 1)
        for s in range(strips):
            lx = Instr("vle", BEATS_PER_INSTR, reg=0, strip_leader=True)
            ly = Instr("vle", BEATS_PER_INSTR, reg=1)
            fm = Instr("vfu", BEATS_PER_INSTR, reg=2, deps=[lx, ly],
                       reads=[0, 1, 2])
            prog += [lx, ly, fm]
            acc_dep = fm
    cyc, frac = _red_cost(cfg)
    prog.append(Instr("vred", cyc, reg=3, deps=[acc_dep], reads=[2],
                      busy_frac=frac))
    return prog


def prog_axpy(vl: int, cfg: SpatzConfig, unroll: int = 1) -> List[Instr]:
    """y <- a*x + y (x, y loaded; y stored).  unroll=2 (paper §IV-F) uses
    the other two register groups so the store no longer blocks the next
    strip's loads."""
    strips = max(vl // ELEMS_PER_INSTR, 1)
    prog: List[Instr] = []
    s = 0
    while s < strips:
        group = []
        for u in range(min(unroll, strips - s)):
            gx, gy = (0, 1) if u % 2 == 0 else (2, 3)
            lx = Instr("vle", BEATS_PER_INSTR, reg=gx,
                       strip_leader=(u == 0))
            ly = Instr("vle", BEATS_PER_INSTR, reg=gy)
            fm = Instr("vfu", BEATS_PER_INSTR, reg=gy, deps=[lx, ly],
                       reads=[gx, gy])
            group.append((lx, ly, fm))
        for lx, ly, fm in group:
            prog += [lx, ly]
        for lx, ly, fm in group:
            prog.append(fm)
        for lx, ly, fm in group:
            prog.append(Instr("vse", BEATS_PER_INSTR, reg=fm.reg,
                              deps=[fm], reads=[fm.reg]))
        s += unroll
    return prog


def prog_gemv(rows: int, cols: int, cfg: SpatzConfig) -> List[Instr]:
    """y = W x, vectorized over rows (column tiles streamed, x broadcast
    from the scalar FPR): one vle + one chained vfmacc per column tile;
    W buffers double-buffer across groups 0/1, accumulator in group 2."""
    strips = max(rows // ELEMS_PER_INSTR, 1)
    prog: List[Instr] = []
    for s in range(strips):
        last = None
        for j in range(cols):
            gw = j % 2
            lw = Instr("vle", BEATS_PER_INSTR, reg=gw,
                       strip_leader=(j % 4 == 0))
            fm = Instr("vfu", BEATS_PER_INSTR, reg=2, deps=[lw],
                       reads=[gw, 2])
            prog += [lw, fm]
            last = fm
        prog.append(Instr("vse", BEATS_PER_INSTR, reg=2, deps=[last],
                          reads=[2]))
    return prog


def prog_gemm(n: int, cfg: SpatzConfig, reuse: int = 8) -> List[Instr]:
    """Tiled GEMM at LMUL=4 (8 register groups): streamed tiles double-
    buffer while ``reuse`` chained vfmaccs amortize each load (high OI)."""
    beats = BEATS_PER_INSTR // 2
    strips = max(n // (ELEMS_PER_INSTR // 2), 1)
    prog: List[Instr] = []
    for s in range(strips):
        gw = s % 2
        lw = Instr("vle", beats, reg=gw, strip_leader=(s % 4 == 0))
        prog.append(lw)
        for r in range(reuse):
            prog.append(Instr("vfu", beats, reg=2 + r % 6, deps=[lw],
                              reads=[gw, 2 + r % 6]))
    return prog


def prog_fft(n: int, cfg: SpatzConfig) -> List[Instr]:
    """Butterfly stages at LMUL=4: 2 loads + 2 flops + 2 stores per pair."""
    import math
    beats = BEATS_PER_INSTR // 2
    stages = max(int(math.log2(max(n, 2))), 1)
    strips = max(n // (ELEMS_PER_INSTR // 2), 1)
    prog: List[Instr] = []
    for st in range(min(stages, 6)):
        for s in range(strips):
            g = (s % 2) * 4
            lx = Instr("vle", beats, reg=0 + g, strip_leader=True)
            ly = Instr("vle", beats, reg=1 + g)
            f1 = Instr("vfu", beats, reg=2 + g, deps=[lx, ly],
                       reads=[0 + g, 1 + g])
            f2 = Instr("vfu", beats, reg=3 + g, deps=[lx, ly],
                       reads=[0 + g, 1 + g])
            sv = Instr("vse", beats, reg=2 + g, deps=[f1], reads=[2 + g])
            sv2 = Instr("vse", beats, reg=3 + g, deps=[f2], reads=[3 + g])
            prog += [lx, ly, f1, f2, sv, sv2]
    return prog


KERNELS = {
    "dotp": lambda cfg, vl=4096: prog_dotp(vl, cfg),
    "axpy": lambda cfg, vl=4096: prog_axpy(
        vl, cfg, unroll=2 if cfg.decoupled else 1),
    "gemv": lambda cfg, vl=4096: prog_gemv(256, 64, cfg),
    "gemm": lambda cfg, vl=4096: prog_gemm(4096, cfg),
    "fft": lambda cfg, vl=4096: prog_fft(1024, cfg),
}


def utilization(kernel: str, cfg: SpatzConfig, vl: int = 4096) -> SimResult:
    prog = KERNELS[kernel](cfg, vl)
    return Spatz(cfg).run(prog)


def figure5(vl: int = 4096) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for kname in KERNELS:
        out[kname] = {}
        for cname, cfg in CONFIGS.items():
            out[kname][cname] = utilization(kname, cfg, vl).fpu_util
    return out
