"""Multi-device tests (8 fake CPU devices via a subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # force the host platform: the fake-device flag is CPU-only, and letting
    # jax probe for an accelerator hangs on machines with libtpu installed
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_collective_matmul_equivalence():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.collective_matmul import (allgather_matmul,
                                                  reduce_scatter_matmul)
        mesh = jax.make_mesh((8,), ("model",))
        B, K, N = 4, 64, 32
        x = jax.random.normal(jax.random.PRNGKey(0), (B, K))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
        # all-gather matmul: x sharded on K
        fn = shard_map(lambda xl, wf: allgather_matmul(xl, wf, "model"),
                       mesh=mesh, in_specs=(P(None, "model"), P()),
                       out_specs=P(), check_rep=False)
        np.testing.assert_allclose(fn(x, w), x @ w, rtol=1e-4, atol=1e-4)
        # reduce-scatter matmul: x K-sharded, w K-sharded, out N-sharded
        fn2 = shard_map(lambda xl, wl: reduce_scatter_matmul(xl, wl, "model"),
                        mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
                        out_specs=P(None, "model"), check_rep=False)
        np.testing.assert_allclose(fn2(x, w), x @ w, rtol=1e-4, atol=1e-4)
        print("OK")
    """)


def test_ddp_compressed_training_step():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.models import RuntimeConfig, build_model
        from repro.models import modules as M
        from repro.optim import OptConfig
        from repro.dist.ddp import make_ddp_train_step
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(model_axis=1)
        cfg = reduced(get_config("qwen1.5-0.5b"), num_layers=2, d_model=64,
                      d_ff=128, vocab_size=128, num_heads=2, num_kv_heads=2,
                      head_dim=32)
        model = build_model(cfg, RuntimeConfig(remat="none"))
        params = M.unbox(model.init(jax.random.PRNGKey(0)))
        step, opt, init_ef = make_ddp_train_step(
            model, OptConfig(lr=1e-3), mesh, compress=True)
        opt_state = opt.init(params)
        ef = init_ef(params)
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "targets": jnp.ones((8, 16), jnp.int32)}
        losses = []
        for _ in range(6):
            params, opt_state, ef, m = step(params, opt_state, ef, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("OK", losses[0], losses[-1])
    """)


def test_elastic_reshard_roundtrip():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ft.elastic import shrink_mesh, reshard_tree
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(model_axis=2)     # (4, 2)
        tree = {"w": jnp.arange(64.0).reshape(8, 8),
                "b": jnp.arange(8.0)}
        sh = {"w": NamedSharding(mesh, P("data", "model")),
              "b": NamedSharding(mesh, P("model"))}
        placed = jax.tree.map(jax.device_put, tree, sh)
        small = shrink_mesh(mesh, lost_data_rows=2)   # (2, 2)
        sh2 = {"w": NamedSharding(small, P("data", "model")),
               "b": NamedSharding(small, P("model"))}
        moved = reshard_tree(placed, sh2)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), tree, moved)
        print("OK")
    """)


def test_sequence_parallel_decode_shard_map():
    """SP decode: cache sharded over devices, LSE-combined — the kernel's
    split-S tree reduction lifted to the mesh (DESIGN.md §4)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.kernels import ops as K
        from repro.kernels import ref as R
        from repro.core.troop import TroopConfig

        mesh = jax.make_mesh((8,), ("s",))
        B, H, KV, hd, S = 2, 8, 4, 64, 1024
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        length = jnp.asarray([700, 1024], jnp.int32)
        cfg = TroopConfig(streams=1, block_k=64)

        def local(q, k, v, length):
            i = jax.lax.axis_index("s")
            off = i * (S // 8)
            acc, m, l = K.decode_attention_stats(q, k, v, length, cfg,
                                                 s_offset=0)
            # shift mask by shard offset: recompute with local lengths
            acc, m, l = K.decode_attention_stats(
                q, k, v, jnp.maximum(length - off, 0), cfg)
            # LSE combine across shards via max/sum reductions
            m_g = jax.lax.pmax(m, "s")
            scale = jnp.exp(m - m_g)
            acc_g = jax.lax.psum(acc * scale, "s")
            l_g = jax.lax.psum(l * scale, "s")
            return acc_g / jnp.maximum(l_g, 1e-30)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(), P(None, "s"), P(None, "s"), P()),
                       out_specs=P(), check_rep=False)
        got = np.asarray(fn(q, k, v, length)).reshape(B, H, hd)
        want = np.asarray(R.decode_attention(q, k, v, length))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
        print("OK")
    """)


def test_pipeline_parallel_equals_sequential():
    """GPipe pipeline over 4 stages == sequential layer stack."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import make_pipeline_fn, bubble_fraction

        S, M, B, D = 4, 8, 16, 32
        mesh = jax.make_mesh((S,), ("stage",))
        ks = jax.random.split(jax.random.PRNGKey(0), S)
        # one stage = one dense layer with tanh
        stage_params = {"w": jax.vmap(
            lambda k: jax.random.normal(k, (D, D)) / jnp.sqrt(D))(ks)}

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        want = x
        for s in range(S):
            want = jnp.tanh(want @ stage_params["w"][s])

        pipe = make_pipeline_fn(stage_fn, mesh, num_microbatches=M)
        got = jax.jit(pipe)(stage_params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
        assert abs(bubble_fraction(S, M) - 3/11) < 1e-9
        print("OK")
    """, n=4)
