"""Speculative decoding + EngineConfig construction API.

Tentpole invariants: greedy speculative serving is token-identical to the
non-speculative engines (dense / paged / chunked+prefix; bf16 and int8 KV),
``speculative_sample`` preserves the target distribution (chi-square), and
rollback-heavy drains leave the page allocator balanced.  API satellites:
``EngineConfig.validate`` error cases, the legacy-kwarg DeprecationWarning
shim, and ``build_engine`` as the one construction path.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve import (EngineConfig, Request, ServingEngine, build_engine,
                         greedy_verify, speculative_sample)
from repro.serve.kvcache import PagedBackend
from repro.serve.speculate import softmax


def setup(**rt_kw):
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none", **rt_kw))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def draft_pair(model, seed=7):
    """A draft sharing the target's arch but with different params — real
    (imperfect) acceptance, still deterministic."""
    draft_params = M.unbox(model.init(jax.random.PRNGKey(seed)))
    return model, draft_params


def serve(eng, prompts, max_new=6, rid0=0):
    reqs = [Request(rid=rid0 + i, prompt=np.asarray(p, np.int32),
                    max_new_tokens=max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == len(reqs) and all(r.done for r in reqs)
    return {r.rid: r.out for r in reqs}


MIXED = [np.arange(1, 4 + 3 * i) % 63 + 1 for i in range(6)]


def spec_engine(model, params, draft_params, *, k=3, page_size=None,
                kv_dtype=None, slots=3, cache_len=64, chunk_size=8,
                num_pages=None):
    be = PagedBackend(page_size=page_size or 16, num_pages=num_pages,
                      kv_dtype=kv_dtype)
    return ServingEngine(
        model, params=params, backend=be,
        config=EngineConfig(slots=slots, cache_len=cache_len,
                            backend="paged", chunked_prefill=True,
                            chunk_size=chunk_size, speculate_k=k),
        draft_model=model, draft_params=draft_params)


def baseline_engine(model, params, *, mode, page_size=None, kv_dtype=None,
                    slots=3, cache_len=64, chunk_size=8):
    cfg = EngineConfig(
        slots=slots, cache_len=cache_len,
        backend="dense" if mode == "dense" else "paged",
        chunked_prefill=mode.startswith("chunked"), chunk_size=chunk_size,
        prefix_cache=(mode == "chunked+prefix"), min_bucket=4)
    be = "dense" if mode == "dense" else \
        PagedBackend(page_size=page_size or 16, kv_dtype=kv_dtype)
    return ServingEngine(model, params=params, backend=be, config=cfg)


# ------------------------------------------------------ token identity
def test_greedy_spec_identical_to_all_baselines():
    """Greedy speculative output == dense == paged == chunked+prefix: the
    verify/rollback machinery changes the schedule, never the tokens."""
    cfg, model, params = setup()
    _, draft_params = draft_pair(model)
    outs = {}
    for mode in ("dense", "paged", "chunked+prefix"):
        eng = baseline_engine(model, params, mode=mode)
        outs[mode] = serve(eng, MIXED)
    spec = spec_engine(model, params, draft_params)
    outs["spec"] = serve(spec, MIXED)
    m = spec.metrics()
    assert m["verify_passes"] > 0 and m["draft_tokens_proposed"] > 0
    assert outs["spec"] == outs["dense"] == outs["paged"] \
        == outs["chunked+prefix"]


def test_greedy_spec_identical_full_acceptance():
    """Same-params draft -> 100% acceptance and > 1 token per target pass,
    still token-identical."""
    cfg, model, params = setup()
    spec = spec_engine(model, params, params)          # draft == target
    outs_spec = serve(spec, MIXED)
    base = baseline_engine(model, params, mode="paged")
    assert outs_spec == serve(base, MIXED)
    m = spec.metrics()
    assert m["acceptance_rate"] == 1.0
    assert m["tokens_per_target_pass"] > 1.0


def test_greedy_spec_identical_int8_kv():
    """Token identity holds through int8 KV pages (quantize-then-gather on
    the verify slab == the decode path bit for bit)."""
    cfg, model, params = setup(kv_cache_dtype="int8")
    _, draft_params = draft_pair(model)
    base = baseline_engine(model, params, mode="paged", page_size=32,
                           kv_dtype="int8")
    outs_base = serve(base, MIXED)
    spec = spec_engine(model, params, draft_params, page_size=32,
                       kv_dtype="int8")
    assert serve(spec, MIXED) == outs_base


# ------------------------------------------- distribution preservation
def test_speculative_sample_preserves_target_distribution():
    """Leviathan rejection sampling: the emitted token at each position is
    distributed per the TARGET distribution regardless of the draft —
    chi-square over >= 10k draws against the exact target pmf."""
    rng = np.random.default_rng(0)
    V, k = 8, 1
    t_logits = np.array([2.0, 1.0, 0.5, 0.0, -0.5, -1.0, -1.5, -2.0])
    d_logits = np.array([-2.0, 0.5, 2.0, 1.0, -1.0, 0.0, 1.5, -0.5])
    t_probs = softmax(t_logits[None, :])                   # (1, V)
    target = np.vstack([t_probs, t_probs])                 # (k+1, V)
    draft = softmax(d_logits[None, :])                     # (k, V)
    counts = np.zeros(V)
    draws = 12000
    for _ in range(draws):
        d_tok = int(rng.choice(V, p=draft[0]))
        emitted, _ = speculative_sample(target, draft,
                                        np.array([d_tok]), rng)
        counts[emitted[0]] += 1
    expected = t_probs[0] * draws
    chi2 = float(np.sum((counts - expected) ** 2 / expected))
    # df = 7; P(chi2_7 > 24.3) ~= 0.001 — generous to stay seed-robust
    assert chi2 < 24.3, f"chi2={chi2:.1f}, counts={counts}"


def test_greedy_verify_prefix_rule():
    # accept while the target argmax reproduces the draft; the first
    # mismatch is replaced by the target's token and the rest dropped
    emitted, accepted = greedy_verify(np.array([5, 6, 9, 9]),
                                      np.array([5, 6, 7]))
    assert accepted == 2 and list(emitted) == [5, 6, 9]
    # full acceptance earns the bonus token (position k)
    emitted, accepted = greedy_verify(np.array([5, 6, 7, 8]),
                                      np.array([5, 6, 7]))
    assert accepted == 3 and list(emitted) == [5, 6, 7, 8]


# --------------------------------------------------- allocator balance
def test_allocator_balanced_after_rollback_heavy_drain():
    """Rollback-heavy drain: prompt+max_new lands exactly on a page
    boundary, so every speculative lookahead allocates pages past the
    request's own need and must give them back.  After the drain the pool
    must be whole — no leaked, double-freed, or still-mapped pages."""
    cfg, model, params = setup()
    _, draft_params = draft_pair(model)        # low acceptance: rejections
    # 26 + 6 = 32 rows = exactly 2 pages at page_size 16: the k=3 verify
    # slab crosses into a 3rd page that acceptance never justifies keeping
    prompts = [np.arange(1, 27) % 63 + 1 for _ in range(5)]
    eng = spec_engine(model, params, draft_params, cache_len=64,
                      chunk_size=8)
    outs = serve(eng, prompts, max_new=6)
    m = eng.metrics()
    assert m["rollback_pages"] > 0, "shape never triggered rollback"
    assert m["pages_in_use"] == 0
    assert m["pages_free"] == m["num_pages"] - 1   # whole pool, minus NULL
    base = baseline_engine(model, params, mode="paged")
    assert outs == serve(base, prompts, max_new=6)


# ----------------------------------------------- EngineConfig / shim
def test_engine_config_validate_errors():
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="sparse").validate()
    with pytest.raises(ValueError, match="chunked_prefill requires"):
        EngineConfig(chunked_prefill=True).validate()
    with pytest.raises(ValueError, match="prefix_cache requires"):
        EngineConfig(backend="paged", prefix_cache=True).validate()
    with pytest.raises(ValueError, match="kernel_decode requires"):
        EngineConfig(kernel_decode=True).validate()
    with pytest.raises(ValueError, match="chunked_prefill"):
        EngineConfig(backend="paged", speculate_k=3,
                     draft_arch="qwen1.5-0.5b").validate()
    with pytest.raises(ValueError, match="single-device"):
        EngineConfig(backend="paged", chunked_prefill=True,
                     speculate_k=3, tp=2).validate()
    with pytest.raises(ValueError, match="draft_arch is set"):
        EngineConfig(draft_arch="qwen1.5-0.5b").validate()


def test_spec_engine_requires_draft():
    cfg, model, params = setup()
    with pytest.raises(ValueError, match="build_engine"):
        ServingEngine(
            model, params=params, backend=PagedBackend(page_size=16),
            config=EngineConfig(backend="paged", chunked_prefill=True,
                                speculate_k=3))


def test_legacy_kwargs_deprecated_but_equivalent():
    """The legacy kwarg shim: warns, forwards into EngineConfig, and the
    engine behaves identically to explicit config construction."""
    cfg, model, params = setup()
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        legacy = ServingEngine(model, params=params, slots=2, cache_len=48)
    assert legacy.config == EngineConfig(slots=2, cache_len=48,
                                         backend="dense")
    modern = ServingEngine(model, params=params,
                           config=EngineConfig(slots=2, cache_len=48))
    prompts = [np.arange(1, 9) % 63 + 1, np.arange(3, 14) % 63 + 1]
    assert serve(legacy, prompts) == serve(modern, prompts)


def test_legacy_kwargs_plus_config_is_typeerror():
    cfg, model, params = setup()
    with pytest.raises(TypeError, match="both"):
        ServingEngine(model, params=params, slots=2,
                      config=EngineConfig(slots=2))


def test_unknown_kwarg_is_typeerror():
    cfg, model, params = setup()
    with pytest.raises(TypeError, match="speculate_k"):
        ServingEngine(model, params=params, speculate_k=3)


def test_build_engine_speculative_end_to_end():
    """build_engine wires the draft pair from the config alone; greedy
    output matches a plain paged build of the same arch."""
    arch = reduced(get_config("qwen1.5-0.5b"),
                   num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                   num_heads=2, num_kv_heads=2, head_dim=32)
    spec = build_engine(arch, EngineConfig(
        slots=2, cache_len=64, backend="paged", chunked_prefill=True,
        chunk_size=8, speculate_k=2), draft=arch)
    base = build_engine(arch, EngineConfig(slots=2, cache_len=64,
                                           backend="paged"))
    prompts = [np.arange(1, 9) % 63 + 1, np.arange(2, 12) % 63 + 1]
    assert serve(spec, prompts) == serve(base, prompts)
    assert spec.metrics()["acceptance_rate"] == 1.0    # same seed-0 params
