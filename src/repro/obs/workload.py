"""Replayable workload traces: seeded request streams for the load harness.

A trace is a list of ``TraceEntry`` rows — (arrival time, prompt length,
shared-prefix id, max_new tokens) — drawn from one of three arrival/length
families (the shapes production serving actually sees):

  * ``heavy_tail``  — Poisson arrivals, Pareto-tailed prompt lengths: most
    prompts short, a fat tail of long ones (the scheduler-stressing mix —
    a long prompt must not head-of-line-block the short ones behind it).
  * ``bursty``      — arrivals clustered in geometric-size bursts separated
    by exponential quiet gaps (thundering herds; exercises admission
    deferral and queue growth).
  * ``diurnal``     — a sinusoidally rate-modulated Poisson process (the
    day/night cycle compressed into one trace; exercises ramp-up/drain).

Everything derives from one ``numpy`` Generator seed: the same
``(dist, seed, requests, knobs)`` always yields byte-identical traces —
the determinism CI gates and the replay tests rely on.  ``materialize``
turns entries into engine ``Request``s with concrete token arrays; prompts
sharing a ``prefix_id`` share their leading ``prefix_len`` tokens (the
prefix-cache workload), and token content is itself seed-deterministic.

Arrival times are in abstract *time units*; the replayer maps them onto
wall-clock seconds or engine cycles (``repro.obs.replay``).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

import numpy as np

DISTRIBUTIONS = ("heavy_tail", "bursty", "diurnal")


@dataclass(frozen=True)
class TraceEntry:
    rid: int
    arrival: float            # time units since trace start (non-decreasing)
    prompt_len: int
    prefix_id: int            # -1: no shared prefix
    max_new: int


@dataclass
class WorkloadTrace:
    entries: List[TraceEntry]
    meta: Dict = field(default_factory=dict)

    def __len__(self):
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)

    # ------------------------------------------------------------ persist
    def to_jsonl(self, path: str):
        with open(path, "w") as f:
            f.write(json.dumps({"meta": self.meta}) + "\n")
            for e in self.entries:
                f.write(json.dumps(asdict(e)) + "\n")

    @staticmethod
    def from_jsonl(path: str) -> "WorkloadTrace":
        with open(path) as f:
            head = json.loads(f.readline())
            entries = [TraceEntry(**json.loads(line)) for line in f if
                       line.strip()]
        return WorkloadTrace(entries, head.get("meta", {}))

    # -------------------------------------------------------- materialize
    def materialize(self, vocab_size: int, *, prefix_len: int = 24,
                    seed: Optional[int] = None):
        """-> list of ``(arrival, Request)``: concrete token arrays, shared
        heads per ``prefix_id``.  Token content derives from ``seed``
        (default: the trace's own seed) so two materializations of one
        trace are identical."""
        from repro.serve.scheduler import Request
        rng = np.random.default_rng(
            self.meta.get("seed", 0) if seed is None else seed)
        hi = max(2, min(vocab_size, 1000))
        heads: Dict[int, np.ndarray] = {}
        for e in self.entries:          # fixed draw order: rid order
            if e.prefix_id >= 0 and e.prefix_id not in heads:
                heads[e.prefix_id] = rng.integers(
                    1, hi, prefix_len).astype(np.int32)
        out = []
        for e in self.entries:
            body_len = e.prompt_len
            head = None
            if e.prefix_id >= 0:
                head = heads[e.prefix_id]
                body_len = max(e.prompt_len - prefix_len, 1)
            body = rng.integers(1, hi, body_len).astype(np.int32)
            prompt = body if head is None else np.concatenate([head, body])
            out.append((e.arrival, Request(rid=e.rid, prompt=prompt,
                                           max_new_tokens=e.max_new)))
        return out


def _lengths(rng, n, dist, lo, hi):
    """Prompt lengths: Pareto-tailed for heavy_tail, log-uniform-ish for
    the arrival-shaped families."""
    if dist == "heavy_tail":
        raw = lo + (rng.pareto(1.8, n) * lo)
    else:
        raw = lo * np.exp(rng.uniform(0, np.log(max(hi / lo, 1.001)), n))
    return np.clip(raw.astype(np.int64), lo, hi)


def generate(dist: str = "heavy_tail", requests: int = 64, seed: int = 0, *,
             mean_interarrival: float = 1.0,
             prompt_len: tuple = (4, 48),
             max_new: tuple = (2, 16),
             num_prefixes: int = 4,
             prefix_fraction: float = 0.5,
             burst_size: int = 8,
             diurnal_period: float = 32.0) -> WorkloadTrace:
    """Seeded trace of ``requests`` entries from distribution ``dist``.

    ``prompt_len``/``max_new``: (lo, hi) clamps.  ``prefix_fraction`` of
    requests get a shared-prefix id in [0, num_prefixes) — their prompts
    will share leading tokens when materialized.  Identical arguments =>
    identical trace (tested)."""
    if dist not in DISTRIBUTIONS:
        raise ValueError(f"unknown distribution {dist!r}; "
                         f"one of {DISTRIBUTIONS}")
    rng = np.random.default_rng(seed)
    n = requests

    if dist == "bursty":
        gaps = []
        while len(gaps) < n:
            burst = max(int(rng.geometric(1.0 / burst_size)), 1)
            gaps.append(rng.exponential(mean_interarrival * burst_size))
            gaps.extend(rng.exponential(mean_interarrival * 0.02, burst - 1))
        arrivals = np.cumsum(np.asarray(gaps[:n]))
    elif dist == "diurnal":
        # inhomogeneous Poisson by per-gap rate modulation: the local rate
        # swings 5x between trough and peak over ``diurnal_period`` units
        t, arrivals = 0.0, []
        for _ in range(n):
            phase = np.sin(2 * np.pi * t / diurnal_period)
            rate = (1.0 / mean_interarrival) * (1.0 + 0.8 * phase)
            t += rng.exponential(1.0 / max(rate, 1e-6))
            arrivals.append(t)
        arrivals = np.asarray(arrivals)
    else:                                         # heavy_tail: plain Poisson
        arrivals = np.cumsum(rng.exponential(mean_interarrival, n))

    lens = _lengths(rng, n, dist, prompt_len[0], prompt_len[1])
    news = rng.integers(max_new[0], max_new[1] + 1, n)
    shared = rng.random(n) < prefix_fraction
    pids = rng.integers(0, max(num_prefixes, 1), n)

    entries = [TraceEntry(rid=i, arrival=float(arrivals[i]),
                          prompt_len=int(lens[i]),
                          prefix_id=int(pids[i]) if shared[i] else -1,
                          max_new=int(news[i]))
               for i in range(n)]
    meta = {"dist": dist, "seed": seed, "requests": requests,
            "mean_interarrival": mean_interarrival,
            "prompt_len": list(prompt_len), "max_new": list(max_new),
            "num_prefixes": num_prefixes,
            "prefix_fraction": prefix_fraction}
    return WorkloadTrace(entries, meta)
