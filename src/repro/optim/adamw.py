"""Optimizers (AdamW / Lion / SGD-m) with a reference jnp path and a fused
TROOP path (``kernels/fused_adamw``): the update is the paper's AXPY-class
workload — pure streaming FMAs over parameter-sized arrays.

State is sharded exactly like the parameters (ZeRO: the FSDP axis of the
params shards the moments too).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | lion | sgdm
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    fused: bool = False            # use the Pallas AXPY-chain kernel


class OptState(NamedTuple):
    step: jax.Array
    mu: any
    nu: any


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return _AdamW(cfg)
    if cfg.name == "lion":
        return _Lion(cfg)
    if cfg.name == "sgdm":
        return _SGDM(cfg)
    raise KeyError(cfg.name)


class _AdamW:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, params):
        zeros = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return OptState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(self, grads, state: OptState, params):
        c = self.cfg
        step = state.step + 1
        lr = lr_at(c, step)
        t = step.astype(jnp.float32)
        bc1 = 1 - c.b1 ** t
        bc2 = 1 - c.b2 ** t

        if c.fused:
            from repro.kernels import ops as K

            def upd(p, g, mu, nu):
                return K.fused_adamw(p, g, mu, nu, lr=lr, b1=c.b1, b2=c.b2,
                                     eps=c.eps, wd=c.weight_decay,
                                     bc1=bc1, bc2=bc2)
            out = jax.tree.map(upd, params, grads, state.mu, state.nu)
            leaf = lambda x: isinstance(x, tuple)
            new_p = jax.tree.map(lambda o: o[0], out, is_leaf=leaf)
            new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=leaf)
            new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=leaf)
            return new_p, OptState(step, new_mu, new_nu), lr

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = c.b1 * mu + (1 - c.b1) * g
            nu = c.b2 * nu + (1 - c.b2) * g * g
            upd_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + c.eps)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr * (upd_ + c.weight_decay * p32)
            return p32.astype(p.dtype), mu, nu

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_mu, new_nu), lr


class _Lion:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                     params), None)

    def update(self, grads, state, params):
        c = self.cfg
        step = state.step + 1
        lr = lr_at(c, step)

        def upd(p, g, mu):
            g = g.astype(jnp.float32)
            u = jnp.sign(c.b1 * mu + (1 - c.b1) * g)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr * (u + c.weight_decay * p32)
            mu = c.b2 * mu + (1 - c.b2) * g
            return p32.astype(p.dtype), mu

        out = jax.tree.map(upd, params, grads, state.mu)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_mu, None), lr


class _SGDM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                     params), None)

    def update(self, grads, state, params):
        c = self.cfg
        step = state.step + 1
        lr = lr_at(c, step)

        def upd(p, g, mu):
            mu = c.b1 * mu + g.astype(jnp.float32)
            p32 = p.astype(jnp.float32) - lr * mu
            return p32.astype(p.dtype), mu

        out = jax.tree.map(upd, params, grads, state.mu)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, OptState(step, new_mu, None), lr
