"""repro.tune — roofline-guided autotuning + kernel dispatch.

The subsystem closes the paper's loop (a kernel is done only at the
roofline) in four pieces:

  registry  — ``@troop_kernel`` decorator; every Pallas kernel declares its
              roofline cost model and tunable TroopConfig space
  search    — enumerate candidates, prune analytically (Spatz cycle model /
              closed-form roofline terms), time survivors, score each as
              fraction-of-roofline
  cache     — JSON-persistent tuned configs keyed kernel|shapes|backend,
              with an in-process LRU (``REPRO_TUNE_CACHE`` overrides the
              path)
  dispatch  — ``get_tuned(name, *args)`` picks the cached best config;
              kernels called without an explicit TroopConfig route through
              it automatically

Quickstart::

    from repro import tune
    import repro.kernels                      # populates the registry
    res = tune.tune("gemv", w, x)             # prune -> time -> cache
    cfg = tune.get_tuned("gemv", w, x)        # cached best (or heuristic)
"""
from repro.tune.cache import (TuneCache, config_from_dict, config_to_dict,
                              default_cache, get_tuned, resolve_path)
from repro.tune.registry import (DEFAULT_SPACE, REGISTRY, KernelSpec,
                                 cache_key, names, troop_kernel)
from repro.tune.search import (Candidate, TuneResult, enumerate_space,
                               measure, predict_fraction, prune,
                               roofline_time, tune)

__all__ = [
    "DEFAULT_SPACE", "REGISTRY", "KernelSpec", "cache_key", "names",
    "troop_kernel",
    "TuneCache", "config_from_dict", "config_to_dict", "default_cache",
    "get_tuned", "resolve_path",
    "Candidate", "TuneResult", "enumerate_space", "measure",
    "predict_fraction", "prune", "roofline_time", "tune",
]
