"""Tensor-parallel serving: shard the jitted engine steps under ``shard_map``.

The paper's decode workload is memory-bound (OI ~= 1); once one device sits
at its roofline the only throughput lever left is more devices each
streaming a *slice* of the bytes — the mesh analogue of Spatz clustering
vector units against a shared L1.  This module makes the serving engine's
step functions (decode / chunked prefill / bucketed prefill) run SPMD over
a 1-D ``tp`` mesh:

  * **attention heads** are column-sharded (``wq``/``wk``/``wv`` output
    dims), GQA-aware: when ``num_kv_heads < tp`` the KV projections and the
    KV page pools stay *replicated* and each device slices the one KV head
    its query block reads (``kv_shards == 1``);
  * **MLP / expert ffn dims** are column-sharded; MoE experts are
    expert-parallel (dim 0 of the stacked expert weights);
  * **KV page pools and scale pages** are sharded on the head axis
    (``kv_shards == tp`` when divisible) so each device streams only its
    slice of the cache — the per-device byte count the engine's streamed-
    bytes model reports;
  * **block tables, the radix prefix index and the BlockAllocator** stay
    host-side and replicated: paging is control flow, not tensor data.

Two execution modes, selected per engine:

  * ``"exact"`` (default): activations stay replicated at layer
    boundaries.  Column-parallel projections compute their local output
    columns (bitwise equal to the corresponding columns of the unsharded
    matmul — XLA's dot is column-separable), attention runs on local
    heads, and the head/ffn shards are re-concatenated with a tiled
    ``all_gather`` before the (replicated) output projections.  Every
    device then holds bitwise-identical logits, which is what makes the
    TP engine *token-identical* to the single-device engine.
  * ``"overlap"``: the row/column-parallel projections route through
    ``repro.dist.collective_matmul``'s ring collectives
    (``allgather_matmul`` for qkv/up/gate, ``reduce_scatter_matmul`` for
    the o/down projections) so the gather/scatter hides behind the
    GEMV/GEMM.  The ring's split-K fp32 accumulation is tolerance-equal
    (not bitwise) to a single dot, so this mode trades exact token
    identity for communication overlap — the tests pin it to fp32
    tolerance against ``jnp.einsum`` references.

Model code discovers TP through a thread-local context (``current()``),
set only while tracing inside the ``shard_map`` body — the same pattern
as ``core.partitioning.PT``: outside a TP engine every call site costs
one attribute check and nothing else.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

AXIS = "tp"

# weight output axes that column-shard (the head / ffn dims)
_COL_AXES = ("qkv_out", "ffn")
_KV_AXES = ("kv_out",)


@dataclass(frozen=True)
class TPPlan:
    """Static sharding decisions for one engine."""
    size: int                      # mesh extent
    kv_shards: int                 # tp when num_kv_heads % tp == 0, else 1
    mode: str                      # "exact" | "overlap"
    axis: str = AXIS
    mesh: Any = field(default=None, compare=False)

    @property
    def kv_replicated(self) -> bool:
        return self.kv_shards == 1


_STATE = threading.local()


def current() -> Optional[TPPlan]:
    """The active plan while tracing inside a TP ``shard_map`` body; None
    everywhere else (single-device paths pay one attribute check)."""
    return getattr(_STATE, "plan", None)


@contextmanager
def enter(plan: TPPlan):
    prev = getattr(_STATE, "plan", None)
    _STATE.plan = plan
    try:
        yield
    finally:
        _STATE.plan = prev


# ---------------------------------------------------------------- helpers
def axis_index():
    return jax.lax.axis_index(current().axis)


def gather_cols(x):
    """Exact-mode shard merge: tiled ``all_gather`` on the last axis —
    device-order concatenation of column shards, bitwise equal to the
    unsharded operator's output."""
    ctx = current()
    return jax.lax.all_gather(x, ctx.axis, axis=x.ndim - 1, tiled=True)


def local_kv_head(k, num_heads: int, num_kv_heads: int):
    """GQA fallback (``kv_shards == 1``): slice the one replicated KV head
    this device's query block attends to.  ``k`` is (..., KV, hd); the
    plan guarantees the local query heads span exactly one KV head."""
    ctx = current()
    m = ctx.size // num_kv_heads            # devices per KV head
    kv_idx = axis_index() // m
    return jax.lax.dynamic_slice_in_dim(k, kv_idx, 1, axis=k.ndim - 2)


# ------------------------------------------------------------------ plan
def plan(model, tp: int, mode: str = "exact") -> TPPlan:
    """Validate the arch/runtime against TP and freeze the sharding plan.

    Raises with a concrete reason for everything the TP engine does not
    (yet) support — a TP engine must never silently compute wrong tokens.
    """
    if mode not in ("exact", "overlap"):
        raise ValueError(f"tp_mode must be 'exact' or 'overlap': {mode!r}")
    cfg, rt = model.cfg, getattr(model, "rt", None)
    if tp < 2:
        raise ValueError("tp plan needs tp >= 2 (tp=1 is the plain engine)")
    if len(jax.devices()) < tp:
        raise ValueError(
            f"tp={tp} but only {len(jax.devices())} devices visible — on "
            "CPU set XLA_FLAGS=--xla_force_host_platform_device_count")
    if cfg.encoder_decoder or getattr(cfg, "frontend", "none") != "none":
        raise ValueError("TP serving supports decoder-only text archs "
                         f"(not {cfg.name!r})")
    if cfg.attention == "mla":
        raise ValueError("TP serving does not shard MLA's latent "
                         "projections yet — use the single-device engine")
    if any(m != "attn" for (m, f) in cfg.layer_kinds()):
        raise ValueError("TP serving supports attention mixers only "
                         "(recurrent state sharding is not head-sliced)")
    if rt is not None and getattr(rt, "paged_kernel_decode", False):
        raise ValueError("paged_kernel_decode is not supported under "
                         "shard_map — the Pallas kernel reads the full "
                         "pool; use the gathered jnp decode path")
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if H % tp:
        raise ValueError(f"num_heads {H} not divisible by tp={tp}")
    if cfg.d_ff % tp:
        raise ValueError(f"d_ff {cfg.d_ff} not divisible by tp={tp}")
    if KV % tp == 0:
        kv_shards = tp
    else:
        # fewer KV heads than devices: replicate KV (pools included) and
        # give each device a query block within a single KV head
        if tp % KV or (H // KV) % (tp // KV):
            raise ValueError(
                f"GQA fallback needs tp % num_kv_heads == 0 and the query "
                f"group divisible by tp // num_kv_heads (H={H}, KV={KV}, "
                f"tp={tp})")
        kv_shards = 1
    if mode == "overlap":
        if cfg.d_model % tp:
            raise ValueError(f"overlap mode shards the contraction axis: "
                             f"d_model {cfg.d_model} % tp={tp} != 0")
        if kv_shards == 1:
            raise ValueError("overlap mode requires num_kv_heads % tp == 0 "
                             "(ring-sharded KV projections)")
    if cfg.moe is not None and getattr(cfg.moe, "num_experts", 0):
        if cfg.moe.num_experts % tp:
            raise ValueError(f"num_experts {cfg.moe.num_experts} not "
                             f"divisible by tp={tp}")
    mesh = jax.make_mesh((tp,), (AXIS,))
    return TPPlan(size=tp, kv_shards=kv_shards, mode=mode, mesh=mesh)


# ------------------------------------------------------------ param specs
def _leaf_spec(axes: Optional[Tuple], ndim: int, plan: TPPlan):
    """PartitionSpec for one weight leaf from its logical axis names."""
    if not axes or ndim == 0:
        return P()
    col = set(_COL_AXES) | (set(_KV_AXES) if plan.kv_shards > 1 else set())
    if "expert" in axes:                       # stacked MoE expert weights
        return P(*[plan.axis if a == "expert" else None for a in axes])
    ent = [None] * ndim
    if ndim == 1:
        if axes[0] in col:                     # column-parallel bias
            ent[0] = plan.axis
    elif axes[-1] in col:                      # column-parallel weight
        ent[-1] = plan.axis
    elif (plan.mode == "overlap" and ndim >= 2 and len(axes) >= 2
          and axes[-2] in _COL_AXES and axes[-1] == "embed"):
        # row-parallel o / down proj: shard the contraction axis (ndim - 2;
        # stacked leaves carry a leading "layers" dim before it)
        ent[ndim - 2] = plan.axis
    return P(*ent)


def param_specs(model, params, plan: TPPlan):
    """Spec tree (a pytree prefix of ``params``: one spec per logical
    weight, covering both children of a ``QuantizedTensor``).  Axis names
    come from the model's ``Param`` boxes via ``eval_shape`` — no
    allocation, and quantized params keep their original dict paths."""
    from repro.models import modules as M
    from repro.quant.tensor import QuantizedTensor

    boxed = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    # axes are tuples — pytrees themselves — so stop flattening at them
    axes_leaves = jax.tree_util.tree_flatten_with_path(
        M.axes_of(boxed),
        is_leaf=lambda x: x is None or isinstance(x, tuple))[0]
    axes_by_path = {_pathkeys(p): a for p, a in axes_leaves}

    def is_logical(x):
        return isinstance(x, QuantizedTensor)

    def visit(path, leaf):
        axes = axes_by_path.get(_pathkeys(path))
        if isinstance(leaf, QuantizedTensor):
            if getattr(leaf, "bits", 8) != 8:
                fmt = "mx4" if getattr(leaf, "fmt", "int") == "mx" else "int4"
                raise ValueError(f"{fmt}-packed weights cannot shard: the "
                                 "packing pairs rows across the shard "
                                 "boundary — use int8 or fp8 under TP")
            ndim = len(leaf.shape)
        else:
            ndim = getattr(leaf, "ndim", 0)
        return _leaf_spec(axes, ndim, plan)

    return jax.tree_util.tree_map_with_path(visit, params,
                                            is_leaf=is_logical)


def _pathkeys(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return tuple(out)


def _arr_spec(leaf, plan: TPPlan):
    """Spec for one cache/state array: every KV-bearing leaf is
    (..., KV, hd) or (..., KV, 1) — shard axis ``ndim - 2`` when the plan
    shards KV, else replicate.  Non-cache leaves (tokens, logits, tables)
    are < 4-D and stay replicated."""
    ndim = getattr(leaf, "ndim", 0)
    if ndim >= 4 and plan.kv_shards > 1:
        ent = [None] * ndim
        ent[ndim - 2] = plan.axis
        return P(*ent)
    return P()


def cache_specs(caches, plan: TPPlan):
    return jax.tree.map(lambda l: _arr_spec(l, plan), caches)


# -------------------------------------------------------------- executor
class TPExecutor:
    """Places params/caches on the mesh and wraps the engine's jitted step
    functions in ``shard_map``.  One instance per ``ServingEngine``."""

    def __init__(self, model, tp: int, mode: str = "exact"):
        self.plan = plan(model, tp, mode)
        self.mesh = self.plan.mesh
        self._pspecs = None
        # optional repro.obs.DispatchProfiler (set by the engine): every
        # sharded step call is bracketed in a "collective" phase tagged
        # with the mesh size
        self.profiler = None

    # ------------------------------------------------------- placement
    def shard_params(self, model, params):
        self._pspecs = param_specs(model, params, self.plan)
        from repro.quant.tensor import QuantizedTensor

        def put(leaf, spec):
            # device_put on a QuantizedTensor applies the spec to both
            # children — values (K, N) and scales (K/g, N) share dims
            return jax.device_put(leaf, NamedSharding(self.mesh, spec))

        return jax.tree.map(
            put, params, self._pspecs,
            is_leaf=lambda x: isinstance(x, QuantizedTensor))

    def shard_caches(self, caches):
        return jax.tree.map(
            lambda l: jax.device_put(
                l, NamedSharding(self.mesh, _arr_spec(l, self.plan))),
            caches)

    # ---------------------------------------------------------- steps
    def jit_step(self, fn: Callable, *, probe: Optional[Callable] = None,
                 donate: Optional[int] = None):
        """``jax.jit(shard_map(fn))`` with specs derived lazily from the
        first call's arguments.  Positional convention (the engine's):
        arg 0 = params, arg 1 = batch (replicated), arg 2 (optional) =
        caches.  ``probe`` is an effect-free twin of ``fn`` used for the
        one ``eval_shape`` (so trace-time counters count compiles only);
        ``donate`` forwards to ``jax.jit(donate_argnums=...)``."""
        state: Dict[str, Any] = {}
        plan_, mesh = self.plan, self.mesh

        def build(args):
            in_specs = [self._pspecs, P()]
            if len(args) > 2:
                in_specs.append(cache_specs(args[2], plan_))
            out_shape = jax.eval_shape(probe or fn, *args)
            out_specs = jax.tree.map(
                lambda l: _arr_spec(l, plan_), out_shape)

            def body(*a):
                with enter(plan_):
                    return fn(*a)

            sm = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                           out_specs=out_specs, check_rep=False)
            return jax.jit(sm, donate_argnums=()
                           if donate is None else (donate,))

        def call(*args):
            f = state.get("f")
            if f is None:
                f = state["f"] = build(args)
            prof = self.profiler
            if prof is not None:
                with prof.phase("collective", devices=plan_.size):
                    return f(*args)
            return f(*args)

        return call
