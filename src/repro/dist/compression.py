"""Int8 gradient compression (per-tensor absmax scale).

Used with error feedback on the data-parallel reduction: the quantization
residual is carried to the next step, so the *sum* of dequantized updates
converges to the sum of true gradients (tested as a hypothesis property).
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_int8(x):
    """x (any shape) -> (int8 values, fp32 scalar scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale
