"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig, RWKVConfig,
                                SHAPES, SSMConfig, ShapeConfig, reduced)

_ARCH_MODULES = {
    "rwkv6-3b":             "repro.configs.rwkv6_3b",
    "qwen1.5-32b":          "repro.configs.qwen15_32b",
    "glm4-9b":              "repro.configs.glm4_9b",
    "qwen1.5-0.5b":         "repro.configs.qwen15_05b",
    "qwen3-14b":            "repro.configs.qwen3_14b",
    "internvl2-76b":        "repro.configs.internvl2_76b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "qwen2-moe-a2.7b":      "repro.configs.qwen2_moe_a27b",
    "jamba-v0.1-52b":       "repro.configs.jamba_v01_52b",
    "whisper-base":         "repro.configs.whisper_base",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(include_skipped: bool = False):
    """All assigned (arch x shape) cells with skip annotations.

    ``long_500k`` requires sub-quadratic attention: only rwkv6 / jamba run it
    (see DESIGN.md §5).
    """
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.subquadratic():
                skip = "full-attention arch: 500k decode is out of scope per assignment"
            if skip is None or include_skipped:
                out.append((arch, sname, skip))
    return out


__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
           "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config", "get_shape",
           "cells", "reduced"]
