from repro.models.registry import Model, RuntimeConfig, build_model, input_specs

__all__ = ["Model", "RuntimeConfig", "build_model", "input_specs"]
