"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV lines.  ``--quick`` skips the slow
interpret-mode kernel timings.
"""
from __future__ import annotations

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (fig5_utilization, fig7_roofline,
                            table1_footprint, table2_energy)
    print("name,value,derived")
    fig5_utilization.run()
    fig7_roofline.run()
    table1_footprint.run()
    table2_energy.run()
    if not quick:
        from benchmarks import kernel_bench, roofline_report
        kernel_bench.run()
        roofline_report.run()


if __name__ == "__main__":
    main()
