"""Flash-decode kernel — the paper's motivating workload (LLM decode phase).

One query token attends over a KV cache: every score/value contraction is a
GEMV on cache lines read exactly once (OI ~= 1 FLOP/byte).  Reaching the HBM
roofline requires exactly the paper's medicine:

  (A) streams=2   — the cache is streamed as two disjoint contiguous
                    S-halves via independent BlockSpecs (two DMAs in flight
                    per grid step, touching disjoint HBM regions — the
                    scrambling guarantee (E) comes for free from the split).
  (B) pipeline    — online-softmax state (m, l, acc) lives in VMEM scratch;
                    compute on block j overlaps the fetch of block j+1.
  (C) shadow acc  — the output commits once at the last S-block; no per-step
                    output DMA backpressure on the VPU/MXU.
  (G) log2 reduce — per-block max/sum are VPU tree reductions; the
                    cross-block combine is the associative online-softmax
                    update (reused cross-device for split-S decode, ops.py).

GQA: q heads grouped over KV heads; per-KV-head contractions run as batched
MXU dot_generals.  The kernel emits UNNORMALIZED (acc, m, l) so the same
code serves full decode (normalize in the wrapper) and split-S partials
(LSE-combined across shards by ``ops.lse_combine``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel

_NEG = -1e30


def _block_update(q, k, v, s0, valid, scale, m_s, l_s, acc):
    """One online-softmax update for a (bs, KV, hd) cache block."""
    KV, G, hd = q.shape
    bs = k.shape[0]
    kT = jnp.moveaxis(k, 1, 0).astype(jnp.float32)       # (KV, bs, hd)
    vT = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    scores = jax.lax.dot_general(
        q.astype(jnp.float32), kT,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale      # (KV, G, bs)
    pos = s0 + jax.lax.broadcasted_iota(jnp.int32, (KV, G, bs), 2)
    scores = jnp.where(pos < valid, scores, _NEG)
    m_new = jnp.maximum(m_s[...], jnp.max(scores, -1, keepdims=True))
    alpha = jnp.exp(m_s[...] - m_new)
    p = jnp.exp(scores - m_new)                          # (KV, G, bs)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, -1, keepdims=True)
    pv = jax.lax.dot_general(
        p, vT, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)              # (KV, G, hd)
    acc[...] = acc[...] * alpha + pv
    m_s[...] = m_new


def _prologue(m_s, l_s, acc):
    m_s[...] = jnp.full_like(m_s, _NEG)
    l_s[...] = jnp.zeros_like(l_s)
    acc[...] = jnp.zeros_like(acc)


def _epilogue(o_ref, m_ref, l_ref, m_s, l_s, acc):
    o_ref[0] = acc[...]
    m_ref[0] = m_s[...]
    l_ref[0] = l_s[...]


def _kernel_1s(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
               m_s, l_s, acc, *, scale, bs):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    _block_update(q_ref[0], k_ref[0], v_ref[0], j * bs, len_ref[b],
                  scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue(o_ref, m_ref, l_ref, m_s, l_s, acc))


def _kernel_2s(len_ref, q_ref, k0, v0, k1, v1, o_ref, m_ref, l_ref,
               m_s, l_s, acc, *, scale, bs, half):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    q, valid = q_ref[0], len_ref[b]
    _block_update(q, k0[0], v0[0], j * bs, valid, scale, m_s, l_s, acc)
    _block_update(q, k1[0], v1[0], half + j * bs, valid, scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue(o_ref, m_ref, l_ref, m_s, l_s, acc))


@functools.partial(jax.jit, static_argnames=("cfg", "s_offset"))
def decode_attention_stats(q, k, v, length, cfg: TroopConfig = TroopConfig(),
                           s_offset: int = 0):
    """Unnormalized partials: (acc (B,KV,G,hd) f32, m (B,KV,G,1), l (B,KV,G,1))."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    half = S // cfg.streams
    bs = max(min(cfg.block_k // 2 * cfg.unroll, half), 1)
    while half % bs:
        bs //= 2
    steps = half // bs
    qg = q.reshape(B, KV, G, hd)
    length = jnp.maximum(length - s_offset, 0)

    scratch = [pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, hd), jnp.float32)]
    q_spec = pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0))
    out_specs = [pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0)),
                 pl.BlockSpec((1, KV, G, 1), lambda b, j: (b, 0, 0, 0)),
                 pl.BlockSpec((1, KV, G, 1), lambda b, j: (b, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
                 jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
                 jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32)]
    lo = pl.BlockSpec((1, bs, KV, hd), lambda b, j: (b, j, 0, 0))
    hi = pl.BlockSpec((1, bs, KV, hd), lambda b, j, o=steps: (b, j + o, 0, 0))

    if cfg.streams == 1:
        acc, m, l = pl.pallas_call(
            functools.partial(_kernel_1s, scale=scale, bs=bs),
            grid=(B, steps),
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), q_spec, lo, lo],
            out_specs=out_specs, out_shape=out_shape, scratch_shapes=scratch,
            interpret=cfg.interpret,
        )(length, qg, k, v)
    else:
        acc, m, l = pl.pallas_call(
            functools.partial(_kernel_2s, scale=scale, bs=bs, half=half),
            grid=(B, steps),
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), q_spec,
                      lo, lo, hi, hi],
            out_specs=out_specs, out_shape=out_shape, scratch_shapes=scratch,
            interpret=cfg.interpret,
        )(length, qg, k, v, k, v)
    return acc, m, l


def _example(small: bool = True):
    B, H, KV, hd, S = (2, 4, 2, 128, 512) if small else (4, 16, 8, 128, 4096)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)
    length = jnp.full((B,), S, jnp.int32)
    return (q, k, v, length), {}


@troop_kernel(
    "decode_attention",
    flops=lambda q, k, v, ln: (4.0 * q.shape[0] * q.shape[1]
                               * k.shape[1] * k.shape[3]),
    bytes=lambda q, k, v, ln: (
        k.shape[0] * k.shape[1] * k.shape[2] * k.shape[3]
        * (itemsize(k) + itemsize(v))
        + q.shape[0] * q.shape[1] * q.shape[2] * 2 * itemsize(q)),
    streamed=lambda q, k, v, ln: [k, v, q, q],   # cache + q in + q-like out
    space={"streams": (1, 2), "unroll": (1, 2), "block_k": (256, 512)},
    ref="decode_attention", example=_example)
def decode_attention(q, k, v, length, cfg: TroopConfig = TroopConfig()):
    """q (B,H,hd); k,v (B,S,KV,hd); length (B,) valid prefix. -> (B,H,hd)."""
    B, H, hd = q.shape
    acc, m, l = decode_attention_stats(q, k, v, length, cfg)
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# Paged variant: block-table gather feeding the same two-stream pipeline
# --------------------------------------------------------------------------
def _epilogue_norm(o_ref, l_s, acc):
    o_ref[0] = acc[...] / jnp.maximum(l_s[...], 1e-30)


def _kernel_paged_1s(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                     m_s, l_s, acc, *, scale, page):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    _block_update(q_ref[0], k_ref[0], v_ref[0], j * page, len_ref[b],
                  scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue_norm(o_ref, l_s, acc))


def _kernel_paged_2s(bt_ref, len_ref, q_ref, k0, v0, k1, v1, o_ref,
                     m_s, l_s, acc, *, scale, page, half):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    q, valid = q_ref[0], len_ref[b]
    _block_update(q, k0[0], v0[0], j * page, valid, scale, m_s, l_s, acc)
    _block_update(q, k1[0], v1[0], (half + j) * page, valid, scale,
                  m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue_norm(o_ref, l_s, acc))


def _paged_example(small: bool = True):
    import numpy as np
    B, H, KV, hd, page, nblk = (2, 4, 2, 128, 16, 4) if small \
        else (4, 16, 8, 128, 16, 16)
    P = 1 + B * nblk
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), jnp.bfloat16)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), jnp.bfloat16)
    # permuted tables: physically scattered pages, logically contiguous
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)
    length = jnp.asarray([max(1, nblk * page - 5 * i) for i in range(B)],
                         jnp.int32)
    return (q, k_pool, v_pool, bt, length), {}


@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode_attention(q, k_pool, v_pool, block_tables, length,
                            cfg: TroopConfig = TroopConfig()):
    B, H, hd = q.shape
    page, KV = k_pool.shape[1], k_pool.shape[2]
    nblk = block_tables.shape[1]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    streams = cfg.streams if nblk % 2 == 0 else 1
    half = nblk // streams

    scratch = [pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, hd), jnp.float32)]
    q_spec = pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0))
    out_spec = pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32)
    # the block-table gather: the page index for grid step (b, j) is read
    # from the scalar-prefetched table, so the DMA engine streams physically
    # scattered pages back-to-back — mechanism (E) at HBM granularity
    lo = pl.BlockSpec((1, page, KV, hd),
                      lambda b, j, bt, ln: (bt[b, j], 0, 0, 0))
    hi = pl.BlockSpec((1, page, KV, hd),
                      lambda b, j, bt, ln, o=half: (bt[b, o + j], 0, 0, 0))

    if streams == 1:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(B, nblk),
            in_specs=[q_spec, lo, lo], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            functools.partial(_kernel_paged_1s, scale=scale, page=page),
            grid_spec=grid_spec, out_shape=out_shape,
            interpret=cfg.interpret,
        )(block_tables, length, qg, k_pool, v_pool)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(B, half),
            in_specs=[q_spec, lo, lo, hi, hi], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            functools.partial(_kernel_paged_2s, scale=scale, page=page,
                              half=half),
            grid_spec=grid_spec, out_shape=out_shape,
            interpret=cfg.interpret,
        )(block_tables, length, qg, k_pool, v_pool, k_pool, v_pool)
    return out.reshape(B, H, hd).astype(q.dtype)


def _paged_streamed(q, kp, vp, bt, ln):
    """Per-slot page traffic (nblk pages each for k and v), not pool size."""
    view = (q.shape[0], bt.shape[1] * kp.shape[1], kp.shape[2], kp.shape[3])
    return [jax.ShapeDtypeStruct(view, kp.dtype),
            jax.ShapeDtypeStruct(view, vp.dtype), q, q, bt]


@troop_kernel(
    "paged_decode_attention",
    flops=lambda q, kp, vp, bt, ln: (4.0 * q.shape[0] * q.shape[1]
                                     * bt.shape[1] * kp.shape[1]
                                     * q.shape[2]),
    bytes=lambda q, kp, vp, bt, ln: (
        q.shape[0] * bt.shape[1] * kp.shape[1] * kp.shape[2] * kp.shape[3]
        * (itemsize(kp) + itemsize(vp))
        + q.shape[0] * q.shape[1] * q.shape[2] * 2 * itemsize(q)
        + bt.shape[0] * bt.shape[1] * itemsize(bt)),
    streamed=_paged_streamed,
    space={"streams": (1, 2)},
    ref="paged_decode_attention", example=_paged_example)
def paged_decode_attention(q, k_pool, v_pool, block_tables, length,
                           cfg: TroopConfig = TroopConfig()):
    """Flash-decode over a paged KV cache (serve.kvcache layout).

    q (B,H,hd); k_pool/v_pool (P,page,KV,hd); block_tables (B,nblk) int32
    mapping logical block -> physical page; length (B,) valid prefix.
    Returns (B,H,hd) in q.dtype.

    Same two-stream online-softmax pipeline as ``decode_attention``, but the
    KV stream is gathered through the scalar-prefetched block table — pages
    are disjoint by construction (the allocator never hands a page to two
    slots), so the decoupled streams read conflict-free regions no matter
    how fragmented the pool is.  ``streams=2`` walks the two halves of the
    slot's logical sequence concurrently (falls back to one stream when the
    table length is odd).
    """
    return _paged_decode_attention(q, k_pool, v_pool, block_tables, length,
                                   cfg)


def _block_update_q8(q, k8, ks, v8, vs, s0, valid, scale, m_s, l_s, acc):
    """Online-softmax update reading an int8 cache block: dequantization
    happens in VMEM after the (halved) HBM stream — mechanism (A)+(E) with
    the §Perf A4 quantized layout."""
    k = k8.astype(jnp.float32) * ks.astype(jnp.float32)
    v = v8.astype(jnp.float32) * vs.astype(jnp.float32)
    _block_update(q, k, v, s0, valid, scale, m_s, l_s, acc)


def _kernel_q8(len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
               o_ref, m_ref, l_ref, m_s, l_s, acc, *, scale, bs):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    _block_update_q8(q_ref[0], k_ref[0], ks_ref[0], v_ref[0], vs_ref[0],
                     j * bs, len_ref[b], scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue(o_ref, m_ref, l_ref, m_s, l_s, acc))


def _int8_example(small: bool = True):
    from repro.quant.tensor import quantize_kv
    (q, k, v, length), _ = _example(small)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    return (q, k8, ks, v8, vs, length), {}


@troop_kernel(
    "decode_attention_int8",
    flops=lambda q, k8, ks, v8, vs, ln: (4.0 * q.shape[0] * q.shape[1]
                                         * k8.shape[1] * k8.shape[3]),
    # §Perf A4 audit: the scale tensors ARE streamed (one row per cache
    # row) — a bytes model that ignores them overstates the roofline win
    # by hd/(hd+2) and mis-scores fraction-of-roofline in repro.tune
    bytes=lambda q, k8, ks, v8, vs, ln: (
        k8.shape[0] * k8.shape[1] * k8.shape[2] * k8.shape[3]
        * (itemsize(k8) + itemsize(v8))
        + k8.shape[0] * k8.shape[1] * k8.shape[2]
        * (itemsize(ks) + itemsize(vs))
        + q.shape[0] * q.shape[1] * q.shape[2] * 2 * itemsize(q)),
    streamed=lambda q, k8, ks, v8, vs, ln: [k8, v8, ks, vs, q, q],
    space={"streams": (1,), "unroll": (1, 2), "block_k": (256, 512)},
    default=TroopConfig(streams=1),
    ref="decode_attention_int8", example=_int8_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def decode_attention_int8(q, k8, k_scale, v8, v_scale, length,
                          cfg: TroopConfig = TroopConfig()):
    """Quantized-cache flash-decode: k8/v8 (B,S,KV,hd) int8 with
    per-(token, head) scales (B,S,KV,1). Returns (B,H,hd) in q.dtype.

    HBM traffic is ~0.5x the bf16 kernel (int8 values + tiny scales); the
    dequant multiply runs on the VPU between the DMA and the MXU."""
    B, H, hd = q.shape
    S, KV = k8.shape[1], k8.shape[2]
    G = H // KV
    scale = hd ** -0.5
    bs = max(min(cfg.block_k // 2 * cfg.unroll, S), 1)
    while S % bs:
        bs //= 2
    steps = S // bs
    qg = q.reshape(B, KV, G, hd)

    scratch = [pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, hd), jnp.float32)]
    q_spec = pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0))
    out_specs = [pl.BlockSpec((1, KV, G, hd), lambda b, j: (b, 0, 0, 0)),
                 pl.BlockSpec((1, KV, G, 1), lambda b, j: (b, 0, 0, 0)),
                 pl.BlockSpec((1, KV, G, 1), lambda b, j: (b, 0, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32),
                 jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
                 jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32)]
    kv_spec = pl.BlockSpec((1, bs, KV, hd), lambda b, j: (b, j, 0, 0))
    sc_spec = pl.BlockSpec((1, bs, KV, 1), lambda b, j: (b, j, 0, 0))

    acc, m, l = pl.pallas_call(
        functools.partial(_kernel_q8, scale=scale, bs=bs),
        grid=(B, steps),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), q_spec,
                  kv_spec, sc_spec, kv_spec, sc_spec],
        out_specs=out_specs, out_shape=out_shape, scratch_shapes=scratch,
        interpret=cfg.interpret,
    )(length, qg, k8, k_scale, v8, v_scale)
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# Quantized paged variant: int8 page pools + scale pages, same block-table
# gather feeding the fused-dequant online-softmax pipeline
# --------------------------------------------------------------------------
def _kernel_paged_q8_1s(bt_ref, len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                        o_ref, m_s, l_s, acc, *, scale, page):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    _block_update_q8(q_ref[0], k_ref[0], ks_ref[0], v_ref[0], vs_ref[0],
                     j * page, len_ref[b], scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue_norm(o_ref, l_s, acc))


def _kernel_paged_q8_2s(bt_ref, len_ref, q_ref, k0, ks0, v0, vs0,
                        k1, ks1, v1, vs1, o_ref, m_s, l_s, acc,
                        *, scale, page, half):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    q, valid = q_ref[0], len_ref[b]
    _block_update_q8(q, k0[0], ks0[0], v0[0], vs0[0], j * page, valid,
                     scale, m_s, l_s, acc)
    _block_update_q8(q, k1[0], ks1[0], v1[0], vs1[0], (half + j) * page,
                     valid, scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue_norm(o_ref, l_s, acc))


def _paged_int8_example(small: bool = True):
    from repro.quant.tensor import quantize_kv
    (q, k_pool, v_pool, bt, length), _ = _paged_example(small)
    k8, ks = quantize_kv(k_pool)
    v8, vs = quantize_kv(v_pool)
    return (q, k8, ks, v8, vs, bt, length), {}


@functools.partial(jax.jit, static_argnames=("cfg",))
def _paged_decode_attention_int8(q, k_pool, k_scales, v_pool, v_scales,
                                 block_tables, length,
                                 cfg: TroopConfig = TroopConfig()):
    B, H, hd = q.shape
    page, KV = k_pool.shape[1], k_pool.shape[2]
    nblk = block_tables.shape[1]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, KV, G, hd)
    streams = cfg.streams if nblk % 2 == 0 else 1
    half = nblk // streams

    scratch = [pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, 1), jnp.float32),
               pltpu.VMEM((KV, G, hd), jnp.float32)]
    q_spec = pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0))
    out_spec = pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, KV, G, hd), jnp.float32)
    # value pages and their scale pages ride the SAME table entry: one
    # allocator, one gather — the scale page is just a second (tiny) DMA
    lo = pl.BlockSpec((1, page, KV, hd),
                      lambda b, j, bt, ln: (bt[b, j], 0, 0, 0))
    lo_s = pl.BlockSpec((1, page, KV, 1),
                        lambda b, j, bt, ln: (bt[b, j], 0, 0, 0))
    hi = pl.BlockSpec((1, page, KV, hd),
                      lambda b, j, bt, ln, o=half: (bt[b, o + j], 0, 0, 0))
    hi_s = pl.BlockSpec((1, page, KV, 1),
                        lambda b, j, bt, ln, o=half: (bt[b, o + j], 0, 0, 0))

    if streams == 1:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(B, nblk),
            in_specs=[q_spec, lo, lo_s, lo, lo_s], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            functools.partial(_kernel_paged_q8_1s, scale=scale, page=page),
            grid_spec=grid_spec, out_shape=out_shape,
            interpret=cfg.interpret,
        )(block_tables, length, qg, k_pool, k_scales, v_pool, v_scales)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(B, half),
            in_specs=[q_spec, lo, lo_s, lo, lo_s, hi, hi_s, hi, hi_s],
            out_specs=out_spec, scratch_shapes=scratch)
        out = pl.pallas_call(
            functools.partial(_kernel_paged_q8_2s, scale=scale, page=page,
                              half=half),
            grid_spec=grid_spec, out_shape=out_shape,
            interpret=cfg.interpret,
        )(block_tables, length, qg, k_pool, k_scales, v_pool, v_scales,
          k_pool, k_scales, v_pool, v_scales)
    return out.reshape(B, H, hd).astype(q.dtype)


def _paged_int8_streamed(q, kp, ks, vp, vs, bt, ln):
    B, nblk, page, KV, hd = (q.shape[0], bt.shape[1], kp.shape[1],
                             kp.shape[2], kp.shape[3])
    view = (B, nblk * page, KV, hd)
    sview = (B, nblk * page, KV, 1)
    return [jax.ShapeDtypeStruct(view, kp.dtype),
            jax.ShapeDtypeStruct(view, vp.dtype),
            jax.ShapeDtypeStruct(sview, ks.dtype),
            jax.ShapeDtypeStruct(sview, vs.dtype), q, q, bt]


@troop_kernel(
    "paged_decode_attention_int8",
    flops=lambda q, kp, ks, vp, vs, bt, ln: (
        4.0 * q.shape[0] * q.shape[1] * bt.shape[1] * kp.shape[1]
        * q.shape[2]),
    # per-slot page traffic at quantized width + scale pages + q io + table
    bytes=lambda q, kp, ks, vp, vs, bt, ln: (
        q.shape[0] * bt.shape[1] * kp.shape[1] * kp.shape[2] * kp.shape[3]
        * (itemsize(kp) + itemsize(vp))
        + q.shape[0] * bt.shape[1] * kp.shape[1] * kp.shape[2]
        * (itemsize(ks) + itemsize(vs))
        + q.shape[0] * q.shape[1] * q.shape[2] * 2 * itemsize(q)
        + bt.shape[0] * bt.shape[1] * itemsize(bt)),
    streamed=_paged_int8_streamed,
    space={"streams": (1, 2)},
    ref="paged_decode_attention_int8", example=_paged_int8_example)
def paged_decode_attention_int8(q, k_pool, k_scales, v_pool, v_scales,
                                block_tables, length,
                                cfg: TroopConfig = TroopConfig()):
    """Flash-decode over int8 page pools with per-(token, head) scale pages.

    q (B,H,hd); k_pool/v_pool (P,page,KV,hd) int8; k_scales/v_scales
    (P,page,KV,1); block_tables (B,nblk) int32; length (B,).  Returns
    (B,H,hd) in q.dtype.

    Identical pipeline to ``paged_decode_attention`` — scalar-prefetched
    block-table gather, two-stream walk of the logical sequence (odd-nblk
    tables fall back to one stream) — but the cache stream is int8 + scale
    pages, ~0.53x the bf16 bytes at hd=128, and the dequant multiply runs
    in-register between the page DMA and the MXU (DESIGN.md §5).
    """
    return _paged_decode_attention_int8(q, k_pool, k_scales, v_pool,
                                        v_scales, block_tables, length, cfg)
