"""Fig. 7 reproduction: normalized roofline (utilization vs OI) for the three
Spatz configurations, plus the TPU-kernel structural roofline points.

The paper normalizes by bandwidth-to-compute ratio: a kernel with OI f
(FLOPs per loaded element) on a machine with ratio r (elements loadable per
FMA slot) is bounded by util <= min(1, f * r / 2).  The model points must
hug that envelope for TROOP and sit below it for the baseline."""
from __future__ import annotations

from repro.core import perfmodel as PM
from benchmarks.paper_data import OI

# elements/cycle that can be loaded per (2 flops/cycle/FPU-lane) of compute
RATIO = {"Spatz_BASELINE": 1.0, "Spatz_2xBW": 2.0, "Spatz_2xBW_TROOP": 2.0}


def bound(kernel: str, cfg_name: str) -> float:
    return min(1.0, OI[kernel] * RATIO[cfg_name] / 2.0)


def run(csv=print):
    res = PM.figure5(4096)
    for kernel in ("axpy", "dotp", "gemv", "fft", "gemm"):
        for cfg_name, util in res[kernel].items():
            b = bound(kernel, cfg_name)
            csv(f"fig7/{kernel}/{cfg_name},{util * 100:.1f},"
                f"OI={OI[kernel]:.2f} bound={b * 100:.0f} "
                f"fraction_of_bound={util / b:.2f}")


if __name__ == "__main__":
    run()
