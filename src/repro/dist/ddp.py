"""DDP train step: data-parallel gradients with optional int8-compressed
reduction + error feedback.

``make_ddp_train_step(model, opt_cfg, mesh, compress=True)`` returns
``(step, opt, init_ef)`` where

    step(params, opt_state, ef, batch) -> (params, opt_state, ef, metrics)

computes per-device gradients inside a ``shard_map`` over the batch axes,
quantizes each gradient tensor to int8 (plus the carried error-feedback
residual) *before* the cross-device mean — an 8x cut of the gradient
all-reduce bytes, the collective-roofline term of ``core.roofline`` — and
dequantizes after, carrying the residual to the next step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.dist.compression import dequantize_int8, quantize_int8
from repro.launch.mesh import batch_axes, data_shards
from repro.optim.adamw import OptConfig, clip_by_global_norm, make_optimizer
from repro.train.step import make_loss_fn


def make_ddp_train_step(model, opt_cfg: OptConfig, mesh, *,
                        compress: bool = True):
    opt = make_optimizer(opt_cfg)
    loss_fn = make_loss_fn(model, model.rt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    axes = batch_axes(mesh)                   # ("data",) / ("pod", "data")
    batch_spec = P(axes)
    # the EF residual is DEVICE-VARYING state (each device carries the
    # quantization error of its own gradient shard), so it gets an explicit
    # leading data-shard dim sharded over the batch axes — declaring it
    # replicated would let any resharding/checkpoint silently collapse all
    # residuals to one device's copy
    ef_spec = P(axes)

    def init_ef(params):
        D = data_shards(mesh)
        return jax.tree.map(
            lambda p: jnp.zeros((D,) + p.shape, jnp.float32), params)

    def local(params, ef, batch):
        ef = jax.tree.map(lambda e: e[0], ef)     # (1, ...) local -> (...)
        (loss, _aux), grads = grad_fn(params, batch)
        loss = jax.lax.pmean(loss, axes)
        if compress:
            def comm(g, e):
                q, s = quantize_int8(g.astype(jnp.float32) + e)
                deq = dequantize_int8(q, s)
                return jax.lax.pmean(deq, axes), g + e - deq
            pairs = jax.tree.map(comm, grads, ef)
            tup = lambda x: isinstance(x, tuple)
            grads = jax.tree.map(lambda t: t[0], pairs, is_leaf=tup)
            ef = jax.tree.map(lambda t: t[1], pairs, is_leaf=tup)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axes), grads)
        return loss, grads, jax.tree.map(lambda e: e[None], ef)

    sharded = shard_map(
        local, mesh=mesh,
        in_specs=(P(), ef_spec, batch_spec),
        out_specs=(P(), P(), ef_spec), check_rep=False)

    @jax.jit
    def step(params, opt_state, ef, batch):
        loss, grads, ef = sharded(params, ef, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state, lr = opt.update(grads, opt_state, params)
        return params, opt_state, ef, {"loss": loss, "grad_norm": gnorm,
                                       "lr": lr}

    return step, opt, init_ef
