"""Chunked WKV6 kernel (RWKV-6 recurrence) — TPU-native adaptation.

The recurrence S_t = diag(w_t) S_{t-1} + k_t (x) v_t is AXPY-class: O(hd^2)
state updated by streaming (r,k,v,w) once.  The reference evaluates it as a
T-step scan (T sequential VPU steps — hopeless on the MXU).  This kernel
uses the chunked linear-attention form with TROOP structure:

  * state (hd x hd) fp32 lives in VMEM scratch across the whole grid row
    (shadow-buffer (C): never written to HBM until the final chunk);
  * per chunk, (r,k,v,w) tiles stream in once ((A)/(B): pipelined fetches);
  * within a chunk the math is re-associated into three MXU matmuls
    (inter-chunk, intra-chunk, state update) — the log2-reduction idea (G)
    applied to a recurrence;
  * all exponentials take non-positive arguments (cumulative log-decays are
    monotone non-increasing), so the chunked form is overflow-safe at any
    decay strength — this is what makes the re-association valid in fp32,
    where a naive exp(+cumsum) separable form overflows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, numel, troop_kernel


def _example(small: bool = True):
    B, T, H, hd = (1, 64, 2, 32) if small else (1, 256, 4, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hd))))
    u = 0.5 * jnp.ones((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    return (r, k, v, w, u, s0), {}


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, so_ref, state, *, bt):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0].astype(jnp.float32)          # (bt, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # decay in (0, 1]
    u = u_ref[0].astype(jnp.float32)          # (1, hd) bonus

    lw = jnp.log(jnp.maximum(w, 1e-30))       # <= 0
    cum = jnp.cumsum(lw, axis=0)              # inclusive, non-increasing
    cum_x = cum - lw                          # exclusive

    # inter-chunk: r_t decayed to the chunk start, applied to carried state
    r_dec = r * jnp.exp(cum_x)                            # exp(<=0)
    y = jnp.dot(r_dec, state[...], preferred_element_type=jnp.float32)

    # intra-chunk: pairwise decayed scores, strictly lower-triangular
    # A[t,s] = sum_i r[t,i] k[s,i] exp(cum_x[t,i] - cum[s,i])   (s < t)
    e = cum_x[:, None, :] - cum[None, :, :]               # (bt, bt, hd)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (bt, bt, 1), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (bt, bt, 1), 1)
    mask = s_idx < t_idx
    e = jnp.where(mask, e, -jnp.inf)                      # mask BEFORE exp
    scores = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(e), axis=-1)
    # current-token bonus (diagonal): (r_t . (u * k_t)) v_t
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)
    y = y + jnp.dot(scores, v, preferred_element_type=jnp.float32) + diag * v
    o_ref[0] = y.astype(o_ref.dtype)

    # state update: S <- diag(prod w) S + (k decayed-to-end)^T v
    decay_all = jnp.exp(cum[-1])                          # (hd,)
    k_dec = k * jnp.exp(cum[-1][None, :] - cum)           # exp(<=0)
    state[...] = decay_all[:, None] * state[...] + jnp.dot(
        k_dec.T, v, preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        so_ref[0] = state[...]


@troop_kernel(
    "rwkv6",
    # state update + readout: O(hd) per (t, head, channel) element
    flops=lambda r, k, v, w, u, s0: 6.0 * numel(r) * r.shape[3],
    bytes=lambda r, k, v, w, u, s0: (
        4 * numel(r) * itemsize(r)          # r, k, v, w in
        + numel(r) * 4 + numel(s0) * 4      # y + final state out (fp32)
        + numel(u) * itemsize(u)),
    streamed=lambda r, k, v, w, u, s0: [
        r, jax.ShapeDtypeStruct(k.shape, r.dtype),
        jax.ShapeDtypeStruct(v.shape, r.dtype),
        jax.ShapeDtypeStruct(w.shape, r.dtype),
        jax.ShapeDtypeStruct(r.shape, jnp.float32),      # y out
        jax.ShapeDtypeStruct(s0.shape, jnp.float32),     # final state out
        u],
    space={"block_n": (64, 128, 256)},
    ref="wkv6", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def wkv6(r, k, v, w, u, state0, cfg: TroopConfig = TroopConfig()):
    """r,k,v,w (B,T,H,hd); u (H,hd); state0 (B,H,hd,hd) fp32.

    Returns (y (B,T,H,hd) f32, state (B,H,hd,hd) f32).
    NOTE: carried-in state0 must be zero in this kernel variant (prefill);
    nonzero initial state is folded in by the ops.py wrapper.
    """
    B, T, H, hd = r.shape
    bt = max(min(cfg.block_n // 8, T), 1)
    while T % bt:
        bt //= 2
    # layout: fold (B,H) into the outer grid dim, time-major tiles
    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, hd)
    rf, kf, vf, wf = fold(r), fold(k), fold(v), fold(w)

    y, state = pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(B * H, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, hd), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, bt, hd), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, bt, hd), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, bt, hd), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, hd), lambda g, j, H=H: (g % H, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bt, hd), lambda g, j: (g, j, 0)),
                   pl.BlockSpec((1, hd, hd), lambda g, j: (g, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B * H, T, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=cfg.interpret,
    )(rf, kf, vf, wf, u)
    y = jnp.moveaxis(y.reshape(B, H, T, hd), 1, 2)
    return y, state.reshape(B, H, hd, hd)
