"""Serving-engine benchmark -> table + BENCH_serve.json.

Runs the continuous-batching engine end to end under both cache backends
(dense, paged) on a reduced arch and reports decode steps/s, tokens/s, and
prefill-compile counts; then times the decode-attention kernels (dense and
paged layouts) at the serving shapes and scores each as a measured
fraction-of-roofline (t_roofline / t_measured, tune subsystem denominators).

    PYTHONPATH=src python benchmarks/serve_bench.py --fast

Interpret-mode wall times on CPU are NOT TPU performance (see
DESIGN.md §3) — the value here is that the whole engine/kernel stack is
exercised for real and the numbers are comparable run over run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def bench_engine(arch: str, backend: str, *, slots, cache_len, requests,
                 max_new, page_size):
    import jax
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.models import RuntimeConfig, build_model
    from repro.models import modules as M
    from repro.serve.kvcache import PagedBackend
    from repro.serve.scheduler import Request, ServingEngine
    from repro.serve.step import make_prefill_step, make_serve_step

    cfg = reduced(get_config(arch))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    be = PagedBackend(page_size=page_size) if backend == "paged" else "dense"
    eng = ServingEngine(
        model, slots=slots, cache_len=cache_len,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params, backend=be)
    rng = np.random.default_rng(0)
    for i in range(requests):
        eng.submit(Request(
            rid=i, prompt=rng.integers(1, min(cfg.vocab_size, 1000),
                                       int(rng.integers(4, 20))),
            max_new_tokens=max_new))
    t0 = time.perf_counter()
    finished = eng.run_until_drained()
    wall = time.perf_counter() - t0
    m = eng.metrics()
    m.update({"arch": cfg.name, "wall_s": wall,
              "requests_submitted": requests,
              "all_finished": len(finished) == requests})
    return m


def bench_decode_kernels(*, slots, cache_len, page_size, iters):
    """Dense vs paged decode-attention at the serving shapes."""
    import jax
    import jax.numpy as jnp
    import repro.kernels  # noqa: F401  (populates the registry)
    from repro.tune import REGISTRY
    from repro.tune.cache import get_tuned
    from repro.tune.search import measure, roofline_time

    B, S, page = slots, cache_len, page_size
    KV, H, hd = 2, 4, 64
    nblk = -(-S // page)
    P = B * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)
    length = jnp.full((B,), S - 1, jnp.int32)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), jnp.bfloat16)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), jnp.bfloat16)
    import numpy as np
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)

    cases = {
        "decode_attention": (q, k, v, length),
        "paged_decode_attention": (q, k_pool, v_pool, bt, length),
    }
    rows = []
    for name, args in cases.items():
        spec = REGISTRY[name]
        cfg = get_tuned(name, *args)
        t = measure(spec, cfg, args, iters=iters)
        roof = roofline_time(spec, args)
        rows.append({
            "kernel": name,
            "shape": f"B={B} S={S} KV={KV} H={H} hd={hd}"
                     + (f" page={page}" if "paged" in name else ""),
            "measured_us": t * 1e6,
            "roofline_us": roof * 1e6,
            "fraction_of_roofline": roof / t if t else 0.0,
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--fast", action="store_true",
                    help="fewer requests / timing iterations (CI smoke)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    import jax
    requests = args.requests or (6 if args.fast else 12)
    max_new = args.max_new or (6 if args.fast else 16)
    iters = 1 if args.fast else 3

    engines = []
    for backend in ("dense", "paged"):
        m = bench_engine(args.arch, backend, slots=args.slots,
                         cache_len=args.cache_len, requests=requests,
                         max_new=max_new, page_size=args.page_size)
        engines.append(m)
        print(f"{backend:<7} {m['decode_steps']:>4} steps  "
              f"{m['decode_steps_per_s']:>8.2f} steps/s  "
              f"{m['tokens_per_s']:>8.2f} tok/s  "
              f"{m['prefill_traces']} prefill compiles")

    kernels = bench_decode_kernels(slots=args.slots, cache_len=args.cache_len,
                                   page_size=args.page_size, iters=iters)
    for r in kernels:
        print(f"{r['kernel']:<24} {r['measured_us']:>10.1f} us  "
              f"roof {r['roofline_us']:>8.3f} us  "
              f"frac {r['fraction_of_roofline']:.3e}")

    payload = {
        "backend": jax.default_backend(),
        "interpret_mode": True,
        "engines": engines,
        "decode_kernels": kernels,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
