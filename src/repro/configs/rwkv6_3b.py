"""rwkv6-3b — RWKV-6 "Finch", attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,              # 2560 / 64 RWKV heads
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention="none",
    pos_emb="none",
    block_pattern=("rwkv",),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    act="relu_sq",
    norm="layernorm",
)
