"""Validate the cycle model against the paper's published claims."""
import dataclasses

import pytest

from repro.core import perfmodel as PM


@pytest.fixture(scope="module")
def fig5():
    return PM.figure5(4096)


def test_dotp_utilizations_match_paper(fig5):
    assert abs(fig5["dotp"]["Spatz_BASELINE"] - 0.33) < 0.06
    assert abs(fig5["dotp"]["Spatz_2xBW"] - 0.59) < 0.08
    assert abs(fig5["dotp"]["Spatz_2xBW_TROOP"] - 0.76) < 0.08


def test_axpy_utilizations_match_paper(fig5):
    assert abs(fig5["axpy"]["Spatz_BASELINE"] - 0.21) < 0.06
    assert abs(fig5["axpy"]["Spatz_2xBW"] - 0.44) < 0.06
    # TROOP AXPY: paper 55%, theoretical bound at 2:1 is 66% — our model
    # reaches the bound (documented optimistic residual)
    assert 0.50 <= fig5["axpy"]["Spatz_2xBW_TROOP"] <= 0.67


def test_gemv_reaches_roofline(fig5):
    assert fig5["gemv"]["Spatz_2xBW_TROOP"] >= 0.96     # paper: 98%
    assert fig5["gemv"]["Spatz_2xBW"] >= 0.85           # paper: 92%


def test_gemm_unharmed(fig5):
    """Paper Table II: compute-bound kernels must not regress under TROOP."""
    for cfg in PM.CONFIGS:
        assert fig5["gemm"][cfg] >= 0.97


def test_dotp_long_vector_at_roofline():
    u = PM.utilization("dotp", PM.BW2X_TROOP, 65536).fpu_util
    assert u >= 0.94            # paper: 96%


def test_headline_speedups(fig5):
    """Paper: GEMV 1.5x, DOTP 2.2x, AXPY 2.6x (TROOP vs baseline)."""
    sp = {k: fig5[k]["Spatz_2xBW_TROOP"] / fig5[k]["Spatz_BASELINE"]
          for k in ("dotp", "axpy", "gemv")}
    assert 1.9 <= sp["dotp"] <= 2.7
    assert 2.2 <= sp["axpy"] <= 3.0
    assert 1.2 <= sp["gemv"] <= 1.7


def test_troop_strictly_improves_memory_bound(fig5):
    for k in ("dotp", "axpy", "gemv", "fft"):
        assert fig5[k]["Spatz_2xBW_TROOP"] >= fig5[k]["Spatz_2xBW"] - 1e-9
        assert fig5[k]["Spatz_2xBW"] > fig5[k]["Spatz_BASELINE"]


def test_mechanism_ablations():
    """Each TROOP mechanism contributes (paper §IV): removing it hurts."""
    full = PM.utilization("dotp", PM.BW2X_TROOP, 8192).fpu_util
    no_scramble = dataclasses.replace(PM.BW2X_TROOP, scrambling=False,
                                      name="x")
    assert PM.utilization("dotp", no_scramble, 8192).fpu_util < full - 0.05
    no_dyn = dataclasses.replace(PM.BW2X_TROOP, dynamic_priority=False,
                                 name="y")
    assert PM.utilization("dotp", no_dyn, 8192).fpu_util <= full + 1e-9
    no_red = dataclasses.replace(PM.BW2X_TROOP, log2_reduction=False,
                                 name="z")
    assert PM.utilization("dotp", no_red, 4096).fpu_util < full


def test_static_priority_fpu_bubble():
    """Fig. 4a: static priority + FPU latency 3 caps chained GEMV below
    peak; dynamic priority + shadow buffers recover it (Fig. 4b)."""
    static = dataclasses.replace(PM.BW2X_TROOP, dynamic_priority=False,
                                 name="s")
    u_static = PM.utilization("gemv", static, 4096).fpu_util
    u_dynamic = PM.utilization("gemv", PM.BW2X_TROOP, 4096).fpu_util
    assert u_dynamic > u_static
    assert u_dynamic >= 0.96
