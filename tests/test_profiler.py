"""repro.obs.profiler: the zero-cost dispatch seam, record correctness,
phase program memoization/replay, reset() safety, timed mode, tracer
feeds, and the measured-vs-modeled decode-step dispatch audit
(bf16 + int8 KV, attention-only and MoE archs)."""
import dis
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config, reduced
from repro.kernels import ops as KO
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.obs.energy import AccountEntry
from repro.serve import EngineConfig
from repro.serve.kvcache import PagedBackend
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step
from repro.tune import REGISTRY
from repro.tune import registry as _reg


def setup():
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def make_engine(model, params, *, profiler=None, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("cache_len", 64)
    return ServingEngine(
        model, prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params,
        backend=PagedBackend(page_size=16), profiler=profiler,
        config=EngineConfig(backend="paged", chunked_prefill=True,
                            chunk_size=16, prefix_cache=True, **kw))


def gemv_args():
    k = jax.random.split(jax.random.PRNGKey(0), 2)
    A = jax.random.normal(k[0], (16, 8), jnp.bfloat16)
    x = jax.random.normal(k[1], (8,), jnp.bfloat16)
    return A, x


# --------------------------------------------------------------------------
# the zero-cost seam
# --------------------------------------------------------------------------
def test_disabled_path_is_one_attr_check():
    """With no profiler installed the dispatch wrapper pays exactly one
    global load of PROFILER — the bytecode proves the seam stays cheap."""
    loads = [ins for ins in dis.Bytecode(KO.gemv)
             if ins.argval == "PROFILER"]
    assert len(loads) == 1, dis.Bytecode(KO.gemv).dis()


def test_install_uninstall_semantics():
    a, b = obs.DispatchProfiler(), obs.DispatchProfiler()
    assert _reg.PROFILER is None
    a.install()
    assert _reg.PROFILER is a
    b.uninstall()                       # someone else's: no-op
    assert _reg.PROFILER is a
    a.uninstall()
    assert _reg.PROFILER is None
    with b:
        assert _reg.PROFILER is b
    assert _reg.PROFILER is None


def test_dispatch_value_identical_and_record_modeled_costs():
    A, x = gemv_args()
    want = np.asarray(KO.gemv(A, x))
    prof = obs.DispatchProfiler()
    with prof:
        got = np.asarray(KO.gemv(A, x))
    assert got.tobytes() == want.tobytes()
    (rec,) = prof.records
    assert rec.kernel == "gemv"
    assert rec.modeled_bytes == float(REGISTRY["gemv"].bytes(A, x))
    assert rec.modeled_flops == float(REGISTRY["gemv"].flops(A, x))
    assert rec.cfg is not None          # the tuned/heuristic config
    assert rec.phase == ""              # unphased -> aggregated directly
    row = prof.phase_rows()[0]
    assert (row["phase"], row["dispatches"]) == ("", 1)


def test_explicit_config_wins():
    from repro.core.troop import TroopConfig
    A, x = gemv_args()
    cfg = TroopConfig(streams=1, unroll=1)
    prof = obs.DispatchProfiler()
    with prof:
        KO.gemv(A, x, cfg=cfg)
    assert prof.records[0].cfg is cfg


def test_engine_token_streams_bit_identical_with_profiler():
    """Installing the profiler must not perturb serving output."""
    cfg, model, params = setup()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 100, int(n)) for n in (5, 9, 21, 13)]

    def run(profiler):
        eng = make_engine(model, params, profiler=profiler)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        if profiler is not None:
            with profiler:
                eng.run_until_drained()
        else:
            eng.run_until_drained()
        return [list(r.out) for r in reqs]

    base = run(None)
    prof = obs.DispatchProfiler()
    assert run(prof) == base
    assert _reg.PROFILER is None        # seam restored


# --------------------------------------------------------------------------
# phases: programs, replay, reset
# --------------------------------------------------------------------------
def test_phase_program_capture_and_replay():
    A, x = gemv_args()
    prof = obs.DispatchProfiler()
    with prof:
        with prof.phase("step"):        # occurrence 1: traces the program
            KO.gemv(A, x)
            KO.gemv(A, x)
        with prof.phase("step"):        # occurrence 2: cache hit, replayed
            pass
    (row,) = prof.phase_rows()
    assert row["phase"] == "step"
    assert row["occurrences"] == 2
    assert row["dispatches"] == 4       # 2 traced + 2 replayed
    per = 2 * float(REGISTRY["gemv"].bytes(A, x))
    assert row["modeled_bytes"] == int(2 * per)
    assert prof.summary()["totals"]["dispatches"] == 4


def test_phase_keys_and_tp_labels():
    A, x = gemv_args()
    prof = obs.DispatchProfiler()
    with prof:
        with prof.phase("prefill", key=16):
            KO.gemv(A, x)
        with prof.phase("prefill", key=32):
            pass                        # different key: no program yet
        with prof.phase("collective", devices=4):
            pass
    rows = {r["phase"]: r for r in prof.phase_rows()}
    assert rows["prefill"]["occurrences"] == 2
    assert rows["prefill"]["dispatches"] == 1
    assert "collective@tp4" in rows


def test_seed_phase_is_pinned():
    A, x = gemv_args()
    sds = jax.ShapeDtypeStruct
    entries = [AccountEntry("gemv", (sds((16, 8), jnp.bfloat16),
                                     sds((8,), jnp.bfloat16)), 3, "mlp")]
    prof = obs.DispatchProfiler()
    prof.seed_phase("decode", entries)
    with prof:
        with prof.phase("decode"):
            KO.gemv(A, x)               # must NOT overwrite the pinned prog
        with prof.phase("decode"):
            pass
    (row,) = prof.phase_rows()
    assert row["occurrences"] == 2
    assert row["dispatches"] == 6       # 3 seeded calls x 2 occurrences


def test_reset_mid_phase_is_safe():
    A, x = gemv_args()
    prof = obs.DispatchProfiler()
    with prof:
        with prof.phase("step"):
            KO.gemv(A, x)
            prof.reset()                # aggregates cleared mid-flight
            KO.gemv(A, x)
    (row,) = prof.phase_rows()
    assert row["occurrences"] == 1
    assert row["dispatches"] == 1       # only the post-reset dispatch
    assert prof._stack == []
    prof.reset()
    assert prof.phase_rows() == []
    with prof:                          # programs survive reset: replay
        with prof.phase("step"):
            pass
    assert prof.phase_rows()[0]["dispatches"] == 1


def test_timed_mode_records_wall():
    A, x = gemv_args()
    prof = obs.DispatchProfiler(timed=True)
    with prof:
        KO.gemv(A, x)
    assert prof.records[0].timed_s > 0
    (row,) = prof.kernel_rows()
    assert row["timed_calls"] == 1
    assert row["achieved_bytes_per_s"] > 0
    assert 0 < row["fraction_of_roofline"] < 1


def test_add_wall_and_tracer_feed():
    A, x = gemv_args()
    tr = obs.Tracer()
    prof = obs.DispatchProfiler(tracer=tr)
    with prof:
        with prof.phase("decode"):
            KO.gemv(A, x)
    prof.add_wall("decode", 0.25)
    assert prof.phase_rows()[0]["wall_s"] >= 0.25
    names = [e[2] for e in tr.events()]
    assert "kernel:gemv" in names
    assert "streamed_bytes" in names and "dispatches" in names
    ev = tr.events("streamed_bytes")[-1]
    assert ev[6]["value"] == int(REGISTRY["gemv"].bytes(A, x))


# --------------------------------------------------------------------------
# tracer dropped-count exports
# --------------------------------------------------------------------------
def test_tracer_dropped_surfaced_in_exports(tmp_path):
    tr = obs.Tracer(capacity=4)
    for i in range(9):
        tr.instant("tick", "queue", rid=i)
    assert tr.dropped == 5
    p = str(tmp_path / "t.jsonl")
    tr.to_jsonl(p)
    last = json.loads(open(p).read().splitlines()[-1])
    assert last == {"ph": "M", "name": "dropped_events", "dropped": 5,
                    "capacity": 4}
    doc = tr.chrome_events()
    meta = [e for e in doc if e["ph"] == "M"
            and e["name"] == "dropped_events"]
    assert meta and meta[0]["args"]["dropped"] == 5
    ctr = [e for e in doc if e["ph"] == "C"
           and e["name"] == "dropped_events"]
    assert ctr and ctr[0]["args"]["value"] == 5


# --------------------------------------------------------------------------
# the dispatch audit: measured multiset == decode_step_account
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "qwen2-moe-a2.7b"])
@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_audit_decode_step_exact(arch, kv_dtype):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, RuntimeConfig(
        remat="none", kv_cache_dtype="int8" if kv_dtype == "int8" else ""))
    a = obs.audit_decode_step(model, cache_len=64, page_size=16)
    assert a.ok, a.report()
    assert a.kv_dtype == kv_dtype
    assert a.dispatches == sum(a.expected.values())
    assert a.measured_bytes == a.expected_bytes > 0


def test_audit_rejects_quantized_weights():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    model = build_model(cfg, RuntimeConfig(remat="none",
                                           quantize_weights="int8"))
    with pytest.raises(ValueError, match="not.*auditable|auditable"):
        obs.audit_decode_step(model)


def test_kernel_routing_restored_on_exit():
    assert not M.kernel_routed()
    with M.kernel_routing():
        assert M.kernel_routed()
    assert not M.kernel_routed()
