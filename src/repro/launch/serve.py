"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Continuous-batching engine around the jitted prefill/decode steps (the
paper's decode workload).  ``--smoke`` uses the reduced config on the host.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))

    extras = None
    if cfg.encoder_decoder or cfg.frontend == "vision":
        import jax.numpy as jnp
        F = cfg.cross_attention_len if cfg.encoder_decoder \
            else cfg.frontend_tokens
        extras = lambda req: {"frontend": 0.1 * jnp.ones(
            (1, F, cfg.d_model), jnp.bfloat16)}
    engine = ServingEngine(
        model, slots=args.slots, cache_len=args.cache_len,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params,
        prefill_extras=extras)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(1, min(cfg.vocab_size, 1000),
                                       int(rng.integers(4, 16))),
            max_new_tokens=args.max_new))
    engine.run_until_drained()
    print(f"served {args.requests} requests in {engine.steps} decode steps")


if __name__ == "__main__":
    main()
