from repro.serve.config import (EngineConfig, LEGACY_ENGINE_KWARGS,
                                build_engine, resolve_page_size)
from repro.serve.kvcache import (BlockAllocator, CacheBackend, ChunkStage,
                                 DenseBackend, PagedBackend, PagedKVCache,
                                 PageSpec, PrefixIndex, bucket_length,
                                 copy_page, make_backend, resolve_kv_dtype)
from repro.serve.scheduler import Request, ServingEngine, splice_cache
from repro.serve.speculate import greedy_verify, speculative_sample
from repro.serve.step import (make_chunk_step, make_draft_step,
                              make_prefill_step, make_serve_step,
                              make_verify_step, sample_keys,
                              tuned_kernel_configs)

__all__ = ["Request", "ServingEngine", "splice_cache",
           "EngineConfig", "LEGACY_ENGINE_KWARGS", "build_engine",
           "resolve_page_size",
           "BlockAllocator", "CacheBackend", "ChunkStage", "DenseBackend",
           "PagedBackend", "PagedKVCache", "PageSpec", "PrefixIndex",
           "bucket_length", "copy_page", "make_backend", "resolve_kv_dtype",
           "greedy_verify", "speculative_sample",
           "make_chunk_step", "make_draft_step", "make_prefill_step",
           "make_serve_step", "make_verify_step", "sample_keys",
           "tuned_kernel_configs"]
