from repro.serve.kvcache import (BlockAllocator, CacheBackend, ChunkStage,
                                 DenseBackend, PagedBackend, PagedKVCache,
                                 PageSpec, PrefixIndex, bucket_length,
                                 copy_page, make_backend)
from repro.serve.scheduler import Request, ServingEngine, splice_cache
from repro.serve.step import (make_chunk_step, make_prefill_step,
                              make_serve_step, sample_keys,
                              tuned_kernel_configs)

__all__ = ["Request", "ServingEngine", "splice_cache",
           "BlockAllocator", "CacheBackend", "ChunkStage", "DenseBackend",
           "PagedBackend", "PagedKVCache", "PageSpec", "PrefixIndex",
           "bucket_length", "copy_page", "make_backend",
           "make_chunk_step", "make_prefill_step", "make_serve_step",
           "sample_keys", "tuned_kernel_configs"]
