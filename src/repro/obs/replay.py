"""Trace replayer: drive any ServingEngine config with a workload trace.

Submits a ``WorkloadTrace``'s requests against their arrival times and
collects the load-harness metrics: per-request TTFT/TPOT percentiles,
queue-depth / pool-occupancy / decoding-slot timelines, and defer +
eviction counts.  Two clocks:

  * ``clock="steps"`` (default) — *virtual* time: one engine cycle (or one
    idle tick when the engine has nothing to do) advances time by
    ``step_period`` trace units.  Fully deterministic: the same seeded
    trace against the same engine config produces bit-identical step-based
    latency percentiles on any machine — these are the numbers
    ``benchmarks/ci_gate.py`` puts SLO bands on.
  * ``clock="wall"`` — arrivals map to real seconds (scaled by
    ``time_scale``); the replayer sleeps through idle gaps.  Wall-clock
    percentiles vary with hardware and stay info-only in CI.

Latency is reported in both units: ``*_steps`` metrics count engine cycles
(deterministic), ``*_s`` metrics are ``perf_counter`` seconds.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.obs.workload import WorkloadTrace


def percentiles(xs, qs=(50, 95, 99), prefix: str = "") -> Dict[str, float]:
    """{prefix_p50: ..., ...}; zeros when ``xs`` is empty."""
    out = {}
    for q in qs:
        key = f"{prefix}p{q}"
        out[key] = float(np.percentile(xs, q)) if len(xs) else 0.0
    return out


@dataclass
class ReplayReport:
    finished: List = field(default_factory=list)
    submitted: int = 0
    timeline: Dict[str, List] = field(default_factory=dict)
    wall_s: float = 0.0
    idle_ticks: int = 0
    engine_metrics: Dict = field(default_factory=dict)

    def _per_request(self):
        rows = []
        for r in self.finished:
            gen = len(r.out)
            row = {"rid": r.rid, "prompt_len": r.prompt_len,
                   "generated": gen,
                   "wait_steps": r.admit_step - r.submit_step,
                   "ttft_steps": r.first_token_step - r.submit_step,
                   "ttft_s": r.ttft_s}
            if gen > 1 and r.finish_step > r.first_token_step:
                row["tpot_steps"] = ((r.finish_step - r.first_token_step)
                                     / (gen - 1))
                dt = r.finish_t - r.first_token_t
                row["tpot_s"] = dt / (gen - 1) if dt > 0 else None
            rows.append(row)
        return rows

    def row(self) -> Dict:
        """Flat summary dict for BENCH_load.json (step metrics are
        deterministic and gateable; ``*_s`` stay info-only)."""
        per = self._per_request()
        ttft_steps = [r["ttft_steps"] for r in per]
        wait_steps = [r["wait_steps"] for r in per]
        tpot_steps = [r["tpot_steps"] for r in per if "tpot_steps" in r]
        ttft_s = [r["ttft_s"] for r in per]
        tpot_s = [r["tpot_s"] for r in per if r.get("tpot_s")]
        m = self.engine_metrics
        out = {
            "requests_submitted": self.submitted,
            "requests_finished": len(self.finished),
            "all_finished": len(self.finished) == self.submitted,
            "wall_s": self.wall_s,
            "idle_ticks": self.idle_ticks,
            **percentiles(ttft_steps, prefix="ttft_steps_"),
            **percentiles(wait_steps, (95,), prefix="wait_steps_"),
            **percentiles(tpot_steps, (50, 95), prefix="tpot_steps_"),
            **percentiles(ttft_s, prefix="ttft_s_"),
            **percentiles(tpot_s, (50, 95), prefix="tpot_s_"),
        }
        tl = self.timeline
        if tl.get("queue_depth"):
            out["queue_depth_max"] = int(max(tl["queue_depth"]))
            out["queue_depth_mean"] = float(np.mean(tl["queue_depth"]))
        if tl.get("decoding"):
            busy = [d for d in tl["decoding"] if d > 0]
            out["mean_decode_occupancy"] = (float(np.mean(busy))
                                            if busy else 0.0)
        if tl.get("pages_in_use"):
            out["pages_in_use_max"] = int(max(tl["pages_in_use"]))
        for k in ("deferrals", "tokens_generated", "tokens_per_s",
                  "prefill_traces", "prefix_hit_rate", "prefix_evictions",
                  "cow_copies", "dispatch_overlap_fraction",
                  "kv_bytes_streamed", "kv_bytes_streamed_per_device",
                  "tp", "kv_shards"):
            if k in m:
                out[k] = m[k]
        return out


class Replayer:
    """Feed a trace to an engine along its arrival schedule.

    ``step_period``: trace time units per engine cycle (steps clock) or
    ``time_scale``: trace units per wall second (wall clock).  The
    ``timeline_every`` knob thins timeline samples for long soaks.
    """

    def __init__(self, engine, *, clock: str = "steps",
                 step_period: float = 1.0, time_scale: float = 1.0,
                 prefix_len: int = 24, timeline_every: int = 1):
        if clock not in ("steps", "wall"):
            raise ValueError(f"clock must be 'steps' or 'wall', got "
                             f"{clock!r}")
        self.engine = engine
        self.clock = clock
        self.step_period = step_period
        self.time_scale = time_scale
        self.prefix_len = prefix_len
        self.timeline_every = max(timeline_every, 1)

    def _sample(self, report: ReplayReport, t: float):
        eng = self.engine
        tl = report.timeline
        tl.setdefault("t", []).append(t)
        tl.setdefault("queue_depth", []).append(len(eng.queue))
        tl.setdefault("active", []).append(
            sum(r is not None for r in eng.active.values()))
        tl.setdefault("decoding", []).append(len(eng._decoding))
        alloc = getattr(eng.backend, "allocator", None)
        if alloc is not None:
            tl.setdefault("pages_in_use", []).append(
                alloc.num_pages - 1 - alloc.num_free)
        tracer = getattr(eng, "tracer", None)
        if tracer is not None:
            tracer.counter("queue_depth", len(eng.queue))
            tracer.counter("decoding_slots", len(eng._decoding))

    def run(self, trace: WorkloadTrace, vocab_size: int,
            max_steps: int = 200_000) -> ReplayReport:
        eng = self.engine
        pending = trace.materialize(vocab_size, prefix_len=self.prefix_len)
        pending.sort(key=lambda ar: (ar[0], ar[1].rid))
        report = ReplayReport(submitted=len(pending))
        t0 = time.perf_counter()
        i = 0
        ticks = 0

        def virtual_now() -> float:
            return (eng.steps + report.idle_ticks) * self.step_period

        while (i < len(pending) or eng.queue
               or any(r is not None for r in eng.active.values())):
            if ticks >= max_steps:
                break
            ticks += 1
            t = (virtual_now() if self.clock == "steps"
                 else (time.perf_counter() - t0) * self.time_scale)
            while i < len(pending) and pending[i][0] <= t:
                eng.submit(pending[i][1])
                i += 1
            if ticks % self.timeline_every == 0:
                self._sample(report, t)
            out = eng.step()
            if out is None:
                # engine idle: advance virtual time to keep arrivals
                # flowing (steps) or sleep until the next arrival (wall)
                report.idle_ticks += 1
                if self.clock == "wall" and i < len(pending):
                    now = (time.perf_counter() - t0) * self.time_scale
                    gap = (pending[i][0] - now) / max(self.time_scale, 1e-9)
                    if gap > 0:
                        time.sleep(min(gap, 0.05))
                continue
            report.finished.extend(out)
        report.wall_s = time.perf_counter() - t0
        report.engine_metrics = eng.metrics()
        return report
