"""TPU-kernel benchmark: structural roofline terms per Pallas kernel,
baseline vs TROOP variant, plus interpret-mode wall time (correctness
exercise only — CPU interpret timing is NOT TPU performance).

Structural terms (exact from shapes): bytes streamed from HBM, FLOPs, OI,
and the v5e roofline-bound time; the TROOP-vs-baseline delta shows the
mechanism value (e.g. fused_adamw: 1 pass vs the ~4 passes of the unfused
reference chain)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.roofline import HBM_BW, PEAK_FLOPS
from repro.core.troop import BASELINE, TROOP
from repro.kernels import ops as K
from repro.tune import get_tuned


def _time(fn, *args, iters=3):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv=print):
    key = jax.random.PRNGKey(0)

    # GEMV: N x K bf16 weights streamed once
    N, Kd = 2048, 4096
    w = jax.random.normal(key, (N, Kd), jnp.bfloat16)
    x = jax.random.normal(key, (Kd,), jnp.bfloat16)
    bytes_ = N * Kd * 2 + Kd * 2 + N * 4
    flops = 2 * N * Kd
    bound_us = max(bytes_ / HBM_BW, flops / PEAK_FLOPS) * 1e6
    # "tuned" rows consume the persistent tune cache (heuristic on a miss)
    for cfg, tag in ((BASELINE, "baseline"), (TROOP, "troop"),
                     (get_tuned("gemv", w, x), "tuned")):
        us = _time(lambda: K.gemv(w, x, cfg))
        csv(f"kernel/gemv/{tag},{us:.0f},interp_us OI={flops / bytes_:.2f} "
            f"v5e_bound_us={bound_us:.1f}")

    # quantized GEMV (repro.quant): bf16 / int8 / int4 side by side — the
    # bytes ratio IS the roofline move (values + scale traffic, DESIGN §5)
    from repro.quant import quantize
    from repro.tune import REGISTRY
    wf = jax.random.normal(key, (N, Kd), jnp.float32)
    for bits in (8, 4):
        qt = quantize(wf, bits=bits, group_size=128, axis=-1)
        q_bytes = REGISTRY["qgemv"].bytes(qt.values, qt.scales, x)
        us = _time(lambda: K.qgemv(qt.values, qt.scales, x, TROOP,
                                   bits=bits))
        csv(f"kernel/qgemv/int{bits},{us:.0f},interp_us "
            f"bytes_ratio_vs_bf16={q_bytes / bytes_:.2f} "
            f"v5e_bound_us={q_bytes / HBM_BW * 1e6:.1f}")

    # DOTP
    n = 1 << 20
    a = jax.random.normal(key, (n,), jnp.bfloat16)
    b = jax.random.normal(key, (n,), jnp.bfloat16)
    bytes_ = 2 * n * 2
    bound_us = bytes_ / HBM_BW * 1e6
    for cfg, tag in ((BASELINE, "baseline"), (TROOP, "troop"),
                     (get_tuned("dotp", a, b), "tuned")):
        us = _time(lambda: K.dotp(a, b, cfg))
        csv(f"kernel/dotp/{tag},{us:.0f},interp_us OI=0.5 "
            f"v5e_bound_us={bound_us:.1f}")

    # AXPY
    for cfg, tag in ((BASELINE, "baseline"), (TROOP, "troop"),
                     (get_tuned("axpy", 1.5, a, b), "tuned")):
        us = _time(lambda: K.axpy(1.5, a, b, cfg))
        csv(f"kernel/axpy/{tag},{us:.0f},interp_us OI=0.33 "
            f"v5e_bound_us={3 * n * 2 / HBM_BW * 1e6:.1f}")

    # decode attention: the paper's LLM-decode GEMV
    B, H, KV, hd, S = 4, 16, 8, 128, 4096
    q = jax.random.normal(key, (B, H, hd), jnp.bfloat16)
    kc = jax.random.normal(key, (B, S, KV, hd), jnp.bfloat16)
    vc = jax.random.normal(key, (B, S, KV, hd), jnp.bfloat16)
    length = jnp.full((B,), S, jnp.int32)
    cache_bytes = 2 * B * S * KV * hd * 2
    flops = 4 * B * H * S * hd
    bound_us = cache_bytes / HBM_BW * 1e6
    for cfg, tag in ((BASELINE, "baseline"), (TROOP, "troop"),
                     (get_tuned("decode_attention", q, kc, vc, length),
                      "tuned")):
        us = _time(lambda: K.decode_attention(q, kc, vc, length, cfg))
        csv(f"kernel/decode_attn/{tag},{us:.0f},interp_us "
            f"OI={flops / cache_bytes:.2f} v5e_bound_us={bound_us:.1f}")

    # int8 quantized flash-decode (§Perf A4): half the cache stream — the
    # bytes come from the registered (audited) cost model, scales included
    from repro.models.attention import quantize_kv
    k8, ksc = quantize_kv(kc)
    v8, vsc = quantize_kv(vc)
    q8_bytes = REGISTRY["decode_attention_int8"].bytes(
        q, k8, ksc, v8, vsc, length)
    us = _time(lambda: K.decode_attention_int8(q, k8, ksc, v8, vsc,
                                               length,
                                               get_tuned("decode_attention_int8",
                                                         q, k8, ksc, v8, vsc,
                                                         length)))
    csv(f"kernel/decode_attn_int8/tuned,{us:.0f},interp_us "
        f"bytes_ratio_vs_bf16={q8_bytes / cache_bytes:.2f} "
        f"v5e_bound_us={q8_bytes / HBM_BW * 1e6:.1f}")

    # fused adamw: 1-pass (7 streams) vs unfused reference (~10 HLO passes)
    n = 1 << 20
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(key, (n,))
    mu = jnp.zeros((n,))
    nu = jnp.zeros((n,))
    hp = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, bc1=0.1, bc2=0.1)
    fused_bytes = n * (4 + 4 + 4 + 4 + 4 + 4 + 4)
    unfused_bytes = fused_bytes * 2.4        # measured HLO pass count ratio
    csv(f"kernel/fused_adamw/bytes,{fused_bytes},"
        f"one_pass vs unfused~{unfused_bytes:.0f} "
        f"v5e_bound_us={fused_bytes / HBM_BW * 1e6:.1f}")
    us = _time(lambda: K.fused_adamw(p, g, mu, nu, **hp, cfg=TROOP))
    csv(f"kernel/fused_adamw/troop,{us:.0f},interp_us")

    # wkv6: chunked MXU form vs T-step scan oracle
    Bw, T, Hw, hdw = 1, 256, 4, 64
    r = jax.random.normal(key, (Bw, T, Hw, hdw))
    kk = jax.random.normal(key, (Bw, T, Hw, hdw))
    vv = jax.random.normal(key, (Bw, T, Hw, hdw))
    ww = jnp.exp(-jnp.exp(jax.random.normal(key, (Bw, T, Hw, hdw))))
    u = 0.5 * jnp.ones((Hw, hdw))
    s0 = jnp.zeros((Bw, Hw, hdw, hdw))
    us = _time(lambda: K.wkv6(r, kk, vv, ww, u, s0, TROOP))
    from repro.kernels import ref as R
    us_ref = _time(lambda: R.wkv6(r, kk, vv, ww, u, s0))
    csv(f"kernel/wkv6/troop,{us:.0f},interp_us scan_ref={us_ref:.0f}us "
        f"chunked_matmul_form=True")


if __name__ == "__main__":
    run()
