"""Generate the data tables of EXPERIMENTS.md from the dry-run JSONs."""
import glob
import json
import os
import sys


def fmt_cell(r):
    rf = r.get("roofline", {})
    if not rf:
        return None
    dom = rf["dominant"][:4]
    bound = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"])
    adj = rf.get("t_memory_adj_s")
    return (f"| {r['arch']} | {r['shape']} | {rf['t_compute_s']:.4f} | "
            f"{rf['t_memory_s']:.4f} | "
            f"{'' if adj is None else f'{adj:.4f}'} | "
            f"{rf['t_collective_s']:.4f} | {dom} | "
            f"{rf['useful_flops_ratio']:.3f} | {bound:.3f} |")


def dryrun_table(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*__single.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                        f"{r.get('error', '')[:60]} | | | | | | |")
            continue
        c = fmt_cell(r)
        if c:
            rows.append(c)
    return "\n".join(rows)


def compile_table(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        mem = r.get("full", {}).get("memory", {})
        t = r.get("times", {})
        co = r.get("full", {}).get("collectives", {}).get("counts", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{t.get('compile_s', 0):.0f}s | "
            f"{mem.get('argument_size_in_bytes', 0) / 1e9:.2f} | "
            f"{mem.get('temp_size_in_bytes', 0) / 1e9:.2f} | "
            f"{sum(co.values())} |")
    return "\n".join(rows)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    print(dryrun_table(d) if which == "roofline" else compile_table(d))
