"""Continuous-batching serving engine (slot-based, decode-centric).

The decode step — the paper's workload — runs every cycle over all active
slots; finished/empty slots admit queued requests, whose prefill output is
spliced into the batch cache at the slot index.  Pure host-side control
around two jitted functions (prefill_step, serve_step), as production
engines do.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _batch_dim(dst_shape, src_shape, slots):
    """Batch dim: where dst == slots and src == 1 (prefer dim 1: stacked
    layer caches are (layers, B, ...))."""
    for d in (1, 0):
        if len(dst_shape) > d and dst_shape[d] == slots \
                and src_shape[d] == 1:
            return d
    raise ValueError(f"cannot locate batch dim: {dst_shape} vs {src_shape}")


def splice_cache(batch_cache, one_cache, slot: int, slots: int):
    """Insert a B=1 prefill cache into slot ``slot`` of the batch cache,
    padding the sequence dim (prompt len -> cache capacity)."""
    def one(dst, src):
        bi = _batch_dim(dst.shape, src.shape, slots)
        src = src.astype(dst.dtype)
        # pad every dim after bi up to dst size (seq dims)
        pads = []
        for d in range(src.ndim):
            tgt = 1 if d == bi else dst.shape[d]
            pads.append((0, tgt - src.shape[d]))
        src = jnp.pad(src, pads)
        start = [0] * dst.ndim
        start[bi] = slot
        return jax.lax.dynamic_update_slice(dst, src, tuple(start))
    return jax.tree.map(one, batch_cache, one_cache)


class ServingEngine:
    def __init__(self, model, *, slots: int, cache_len: int,
                 prefill_step, serve_step, params, stop_token: int = -1,
                 prefill_extras=None):
        """``prefill_extras(req) -> dict``: extra prefill batch entries
        (modality frontend stubs for enc-dec / VLM archs)."""
        self.model = model
        self.slots = slots
        self.cache_len = cache_len
        self.params = params
        self.prefill_extras = prefill_extras
        self.prefill_step = jax.jit(prefill_step)
        self.serve_step = jax.jit(serve_step, donate_argnums=(2,))
        self.caches = model.init_caches(slots, cache_len)
        self.active: Dict[int, Optional[Request]] = {
            i: None for i in range(slots)}
        self.pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        self.queue: deque = deque()
        self.stop_token = stop_token
        self.steps = 0

    # -------------------------------------------------------------- admit
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot, occupant in self.active.items():
            if occupant is not None or not self.queue:
                continue
            req = self.queue.popleft()
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            if self.prefill_extras is not None:
                batch.update(self.prefill_extras(req))
            next_tok, cache1 = self.prefill_step(self.params, batch)
            self.caches = splice_cache(self.caches, cache1, slot, self.slots)
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)
            tok = int(np.asarray(next_tok)[0, 0])
            req.out.append(tok)
            self.last_tok[slot] = tok

    # -------------------------------------------------------------- decode
    def step(self):
        self._admit()
        if not any(r is not None for r in self.active.values()):
            return False
        batch = {"tokens": jnp.asarray(self.last_tok[:, None]),
                 "pos": jnp.asarray(self.pos)}
        next_tok, self.caches = self.serve_step(
            self.params, batch, self.caches)
        toks = np.asarray(next_tok)[:, 0]
        for slot, req in self.active.items():
            if req is None:
                continue
            tok = int(toks[slot])
            req.out.append(tok)
            self.last_tok[slot] = tok
            self.pos[slot] += 1
            if len(req.out) >= req.max_new_tokens or tok == self.stop_token \
                    or self.pos[slot] >= self.cache_len - 1:
                req.done = True
                self.active[slot] = None
        self.steps += 1
        return True

    def run_until_drained(self, max_steps: int = 10_000):
        finished = []
        while (self.queue or any(r is not None
                                 for r in self.active.values())):
            if not self.step():
                break
            if self.steps > max_steps:
                break
        return self.steps
