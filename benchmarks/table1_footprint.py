"""Table I analogue: resource footprint of the TROOP mechanisms.

Hardware area doesn't transfer to TPU; the faithful analogue is the VMEM /
scratch / register budget each kernel variant claims (the quantity a TPU
kernel "pays" for its mechanisms).  Reported: bytes of VMEM scratch +
in-flight DMA window bytes per kernel, baseline vs TROOP, with the paper's
area ratios alongside."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.troop import BASELINE, TROOP
from benchmarks.paper_data import TABLE1_AREA_RATIO


def window_bytes(cfg, streams_operands, scratch_elems, dtype_bytes=2):
    """In-flight VMEM: (streams x operands x block window x double-buffer)
    + scratch accumulators."""
    win = cfg.streams * streams_operands * cfg.block_k * cfg.unroll * \
        dtype_bytes * 2                      # x2: pipeline double-buffering
    return win + scratch_elems * 4


KERNELS = {
    # kernel: (streamed operands, scratch fp32 elems (shadow-accumulators))
    "gemv": (2, 256),                        # W,x windows; (bn,1) acc
    "dotp": (2, 1),                          # x,y; scalar acc
    "axpy": (3, 0),                          # x,y in + y out
    "decode_attention": (2, 8 * 128 + 16),   # K,V; (KV,G,hd) acc + m,l
    "fused_adamw": (7, 0),                   # p,g,mu,nu in; p,mu,nu out
}


def run(csv=print):
    for name, (ops, scratch) in KERNELS.items():
        b = window_bytes(BASELINE, ops, 0)
        t = window_bytes(TROOP, ops, scratch)
        csv(f"table1/{name},{t},vmem_bytes_troop base={b} "
            f"ratio={t / b:.2f}")
    for blk, ratio in TABLE1_AREA_RATIO.items():
        csv(f"table1/paper_area/{blk},{ratio},kGE_ratio_from_paper")


if __name__ == "__main__":
    run()
