"""Fault-tolerance integration: failures mid-run, restart, determinism."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.ft import FailureInjector, StepWatchdog
from repro.models import RuntimeConfig, build_model
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def make_trainer(tmp_path, fail_at=(), total=24):
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=256,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    tcfg = TrainerConfig(total_steps=total, ckpt_every=8,
                         ckpt_dir=str(tmp_path), log_every=4,
                         async_ckpt=False)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4)
    return Trainer(model, OptConfig(lr=1e-3, warmup_steps=4),
                   data_cfg, tcfg,
                   failure_injector=FailureInjector(fail_at=set(fail_at)))


def test_run_to_completion(tmp_path):
    t = make_trainer(tmp_path, total=12)
    params, opt_state, hist = t.run()
    assert hist[-1]["step"] == 12
    assert np.isfinite(hist[-1]["loss"])


def test_loss_decreases(tmp_path):
    t = make_trainer(tmp_path, total=24)
    _, _, hist = t.run()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    t = make_trainer(tmp_path, fail_at=(10, 17), total=24)
    params, _, hist = t.run()
    assert hist[-1]["step"] == 24
    assert t.injector.fired == {10, 17}
    assert t.ckpt.latest_step() == 24


def test_recovery_matches_uninterrupted_run(tmp_path):
    """Determinism: a run with failures equals one without (same data)."""
    a = make_trainer(tmp_path / "a", total=16)
    pa, _, _ = a.run()
    b = make_trainer(tmp_path / "b", fail_at=(11,), total=16)
    pb, _, _ = b.run()
    # recovery restarts from step 8 checkpoint; deterministic data =>
    # identical final params
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6), pa, pb)


def test_watchdog_escalates_on_stragglers():
    wd = StepWatchdog(threshold=2.0, patience=2, warmup=0)
    out = []
    for s in range(8):
        dt = 1.0 if s < 5 else 10.0      # straggler from step 5
        out.append(wd.record(s, dt))
    assert out[-1] is True               # escalation after patience
    assert len(wd.events) >= 2
