"""Configuration dataclasses for models, shapes and runtime.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s.  Configs are frozen dataclasses so
they can be hashed and used as jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_experts_per_tok: int = 0
    d_ff: int = 0                      # per-expert hidden size
    num_shared_experts: int = 0        # always-on experts (DeepSeek/Qwen-MoE)
    shared_d_ff: int = 0               # total hidden of the shared expert block
    shared_expert_gate: bool = False   # Qwen-MoE sigmoid gate on shared output
    norm_topk_prob: bool = True        # renormalise top-k gate probs
    routed_scaling_factor: float = 1.0
    capacity_factor: float = 1.25      # dispatch capacity (dropped tokens -> 0)
    router_aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0               # 0 => direct q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective-state-space block (Jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV-6 "Finch" time-mix / channel-mix block."""
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    gate_lora: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavour ----------------------------------------------
    attention: str = "gqa"             # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"              # rope | learned | sinusoidal | none
    max_position_embeddings: int = 1 << 20

    # --- block pattern ----------------------------------------------------
    # sequence of mixer kinds per layer period ("attn"|"mamba"|"rwkv"); the
    # model tiles this pattern over num_layers.  () == ("attn",).
    block_pattern: Tuple[str, ...] = ()
    # which layers (mod moe_period == moe_offset) use the MoE ffn
    moe: Optional[MoEConfig] = None
    moe_period: int = 1
    moe_offset: int = 0
    first_dense_layers: int = 0        # leading layers forced to dense ffn

    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # --- ffn / norm flavour ----------------------------------------------
    act: str = "swiglu"                # swiglu | gelu | relu_sq
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- encoder-decoder ---------------------------------------------------
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    cross_attention_len: int = 1500    # whisper: encoder frames seen by decoder

    # --- modality frontend (STUB: precomputed embeddings via input_specs) --
    frontend: str = "none"             # none | audio | vision
    frontend_tokens: int = 0           # e.g. 256 vision patches prepended

    # --- numerics ----------------------------------------------------------
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"       # master weights

    # sub-quadratic? (drives long_500k applicability)
    def subquadratic(self) -> bool:
        pat = self.block_pattern or ("attn",)
        return any(k in ("mamba", "rwkv") for k in pat)

    def pattern(self) -> Tuple[str, ...]:
        return self.block_pattern or ("attn",)

    def layer_kinds(self):
        """(mixer, ffn) kind for every layer index."""
        pat = self.pattern()
        out = []
        for i in range(self.num_layers):
            mixer = pat[i % len(pat)]
            if self.moe is not None and i >= self.first_dense_layers and (
                    i % self.moe_period == self.moe_offset):
                ffn = "moe"
            else:
                ffn = "mlp"
            if mixer == "rwkv":
                ffn = "rwkv_cm"        # RWKV channel-mix replaces the MLP
            out.append((mixer, ffn))
        return out

    # Parameter count (analytical, for MODEL_FLOPS = 6*N*D).
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        n = 0
        kinds = self.layer_kinds()
        for mixer, ffn in kinds:
            if mixer == "attn":
                if self.mla is not None:
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    n += d * self.num_heads * qd                       # W_Q
                    n += d * (m.kv_lora_rank + m.qk_rope_head_dim)     # W_DKV
                    n += m.kv_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)             # W_UK/UV
                    n += self.num_heads * m.v_head_dim * d             # W_O
                else:
                    n += d * self.num_heads * hd * 2                   # Q,O
                    n += d * self.num_kv_heads * hd * 2                # K,V
            elif mixer == "mamba":
                s = self.ssm or SSMConfig()
                di = s.expand * d
                dt_rank = s.dt_rank or -(-d // 16)
                n += d * 2 * di            # in_proj
                n += di * s.d_conv         # conv
                n += di * (dt_rank + 2 * s.d_state)  # x_proj
                n += dt_rank * di + di     # dt_proj
                n += di * s.d_state + di   # A, D
                n += di * d                # out_proj
            elif mixer == "rwkv":
                r = self.rwkv or RWKVConfig()
                n += d * d * 5             # r,k,v,g,o
                n += 5 * r.mix_lora * d * 2 + r.decay_lora * d * 2 + \
                    r.gate_lora * 0
            if ffn == "mlp":
                mult = 3 if self.act == "swiglu" else 2
                n += d * self.d_ff * mult
            elif ffn == "rwkv_cm":
                n += d * self.d_ff + self.d_ff * d + d * d  # k, v, r gate
            elif ffn == "moe":
                mo = self.moe
                mult = 3 if self.act == "swiglu" else 2
                per_expert = d * mo.d_ff * mult
                routed = (mo.num_experts_per_tok if active_only
                          else mo.num_experts) * per_expert
                shared = d * mo.shared_d_ff * mult if mo.shared_d_ff else 0
                n += routed + shared + d * mo.num_experts  # + router
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.encoder_decoder:
            # encoder layers: self-attn + mlp ; decoder already counted above,
            # add cross-attention per decoder layer.
            enc = 0
            enc += self.num_encoder_layers * (
                d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2 +
                d * self.d_ff * (3 if self.act == "swiglu" else 2))
            xattn = self.num_layers * (
                d * self.num_heads * hd * 2 + d * self.num_kv_heads * hd * 2)
            n += enc + xattn
        return int(n)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        num_layers=min(cfg.num_layers, len(cfg.pattern()) * 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads >= 4 else cfg.num_kv_heads,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_position_embeddings=2048,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8,
            num_experts_per_tok=min(cfg.moe.num_experts_per_tok, 2),
            d_ff=64, shared_d_ff=64 if cfg.moe.shared_d_ff else 0)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                   qk_nope_head_dim=32, qk_rope_head_dim=16,
                                   v_head_dim=32)
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2)
    if cfg.rwkv is not None:
        changes["rwkv"] = RWKVConfig(head_dim=32, decay_lora=16, mix_lora=8)
        changes["num_heads"] = 4
        changes["head_dim"] = 32
    if cfg.encoder_decoder:
        changes["num_encoder_layers"] = 2
        changes["num_layers"] = 2
        changes["cross_attention_len"] = 64
    if cfg.frontend_tokens:
        changes["frontend_tokens"] = 16
    changes.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)
