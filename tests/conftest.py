import os

# Tests see the host's single device; ONLY dryrun forces 512 (see launch/dryrun).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
