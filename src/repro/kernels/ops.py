"""Public jit'd kernel API (TroopConfig-switchable: baseline vs TROOP).

This is the layer the framework calls; every function has a pure-jnp oracle
in ``ref.py`` and both are exercised by the test suite.  ``lse_combine``
lifts the kernel's online-softmax combine to the mesh level for
sequence-parallel decode (split-S across devices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.troop import BASELINE, TROOP, TroopConfig
from repro.kernels.axpy import axpy
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_int8,
                                            decode_attention_stats,
                                            paged_decode_attention,
                                            paged_decode_attention_int8)
from repro.kernels.dotp import dotp
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_adamw import fused_adamw
from repro.kernels.gemv import gemv
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.prefill_attention import prefill_attention_paged
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6 import wkv6
from repro.quant.kernels import (batched_mx_qgemv, batched_qgemv,
                                 grouped_expert_qgemv, mx_qgemv,
                                 mx_qgemv_swiglu, qgemv)

__all__ = ["gemv", "dotp", "axpy", "rmsnorm", "fused_adamw",
           "decode_attention", "decode_attention_stats", "decode_attention_int8",
           "paged_decode_attention", "paged_decode_attention_int8",
           "prefill_attention_paged",
           "flash_attention", "qgemv", "batched_qgemv",
           "mx_qgemv", "batched_mx_qgemv", "mx_qgemv_swiglu",
           "grouped_expert_qgemv",
           "wkv6", "wkv6_with_state", "mamba_scan", "batched_gemv",
           "lse_combine", "BASELINE", "TROOP", "TroopConfig"]


def batched_gemv(w, xs, cfg: TroopConfig = TroopConfig()):
    """w (N,K), xs (B,K) -> (B,N): small-batch decode projections."""
    return jax.vmap(lambda x: gemv(w, x, cfg))(xs)


def wkv6_with_state(r, k, v, w, u, state0, cfg: TroopConfig = TroopConfig()):
    """WKV6 with nonzero carried-in state (decode chaining).

    The kernel assumes zero initial state; the carried state contributes
    y_t += (r_t * decay-to-start_t) @ state0, folded in here as one batched
    matmul (exact, associative split of the recurrence).
    """
    y, state = wkv6(r, k, v, w, u, jnp.zeros_like(state0), cfg)
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    cum_x = jnp.cumsum(lw, axis=1) - lw                    # exclusive, <= 0
    r_dec = r.astype(jnp.float32) * jnp.exp(cum_x)
    y = y + jnp.einsum("bthi,bhij->bthj", r_dec, state0.astype(jnp.float32))
    decay_all = jnp.exp(jnp.sum(lw, axis=1))               # (B,H,hd)
    state = state + decay_all[..., None] * state0.astype(jnp.float32)
    return y, state


def lse_combine(partials):
    """Combine split-S decode partials [(acc, m, l), ...] -> (B,KV,G,hd).

    The associative log-sum-exp combine (paper mechanism (G) lifted to the
    mesh): with the cache sharded over S, each device produces a partial and
    the combine tree costs O(hd) per device — this is what makes
    sequence-parallel decode of 500k-token caches collective-cheap.
    """
    acc, m, l = partials[0]
    for acc2, m2, l2 in partials[1:]:
        m_new = jnp.maximum(m, m2)
        a1, a2 = jnp.exp(m - m_new), jnp.exp(m2 - m_new)
        acc = acc * a1 + acc2 * a2
        l = l * a1 + l2 * a2
        m = m_new
    return acc / jnp.maximum(l, 1e-30)
