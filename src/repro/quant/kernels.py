"""Fused-dequant GEMV kernels — quantization applied AT the roofline.

``qgemv``/``batched_qgemv`` stream int8 (or packed-int4) weights plus their
per-group scales and dequantize *in register*, between the DMA and the MXU:

  (A) streams=2   — the quantized weight, its scale blocks and x are each
                    fetched as two disjoint contiguous K-halves (independent
                    BlockSpecs -> two DMAs in flight per grid step).
  (C) shadow acc  — fp32 accumulator in VMEM scratch; y commits once per
                    row-tile.
  (D) alignment   — the scale group is a multiple of the int8 layout
                    granule and divides block_k, so each (block_n, block_k)
                    weight tile consumes whole scale blocks: the dequant
                    multiply is one reshape-broadcast on the VPU, never a
                    gather across tile edges (DESIGN.md §5).
  (E) layout      — int4 packs two values per byte along K, so a packed
                    block is still one dense contiguous HBM region.

At OI ~= 1 the runtime bound is bytes/BW, so int8 halves and int4 quarters
the attainable decode-GEMV time — the registered ``bytes=`` models count
the quantized widths *and* the scale traffic, which is what ``repro.tune``
scores fraction-of-roofline against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.quant.tensor import E8M0_BIAS, quantize, quantize_mx
from repro.tune.registry import itemsize, numel, troop_kernel


def _dequant_block(w_ref, s_ref, *, bits: int, g: int):
    """(bn, bk[, packed]) int8 + (bn, bk//g) scales -> (bn, bk) fp32."""
    w8 = w_ref[...]
    if bits == 4:
        lo = jnp.right_shift(jnp.left_shift(w8, 4), 4)   # sign-extend
        hi = jnp.right_shift(w8, 4)
        w8 = jnp.stack([lo, hi], axis=-1).reshape(w8.shape[0], -1)
    bn, bk = w8.shape
    s = s_ref[...].astype(jnp.float32)                   # (bn, bk // g)
    w = w8.astype(jnp.float32).reshape(bn, bk // g, g) * s[:, :, None]
    return w.reshape(bn, bk)


def _kernel_1s(w_ref, s_ref, x_ref, o_ref, acc, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    w = _dequant_block(w_ref, s_ref, bits=bits, g=g)
    acc[...] += jnp.dot(w, x_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _kernel_2s(w0, s0, x0, w1, s1, x1, o_ref, acc, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    a = jnp.dot(_dequant_block(w0, s0, bits=bits, g=g),
                x0[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    b = jnp.dot(_dequant_block(w1, s1, bits=bits, g=g),
                x1[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    acc[...] += a + b

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _qgemv_2d(wq, scales, x2, cfg: TroopConfig, bits: int):
    """wq (N, Ks) int8, scales (N, K//g), x2 (K, B) -> (N, B) fp32."""
    N = wq.shape[0]
    K, B = x2.shape
    assert wq.shape[1] == (K // 2 if bits == 4 else K), \
        f"weight K extent {wq.shape[1]} inconsistent with bits={bits}, K={K}"
    g = K // scales.shape[1]
    pack = 2 if bits == 4 else 1

    bn = min(cfg.block_n, N)
    while N % bn:
        bn //= 2
    streams = cfg.streams if (K // g) % 2 == 0 and cfg.streams == 2 else 1
    Kh = K // streams
    bk = max(min(cfg.block_k * cfg.unroll, Kh) // g * g, g)
    while Kh % bk:
        bk -= g
    steps = Kh // bk
    body = functools.partial(
        _kernel_1s if streams == 1 else _kernel_2s, bits=bits, g=g)

    # block index maps share j: the packed weight, its scale blocks and the
    # x slice advance in lockstep along K (bk elements = bk//pack bytes =
    # bk//g scale entries per step)
    w_lo = pl.BlockSpec((bn, bk // pack), lambda i, j: (i, j))
    w_hi = pl.BlockSpec((bn, bk // pack), lambda i, j, o=steps: (i, j + o))
    s_lo = pl.BlockSpec((bn, bk // g), lambda i, j: (i, j))
    s_hi = pl.BlockSpec((bn, bk // g), lambda i, j, o=steps: (i, j + o))
    x_lo = pl.BlockSpec((bk, B), lambda i, j: (j, 0))
    x_hi = pl.BlockSpec((bk, B), lambda i, j, o=steps: (j + o, 0))

    if streams == 1:
        in_specs, ops = [w_lo, s_lo, x_lo], (wq, scales, x2)
    else:
        in_specs = [w_lo, s_lo, x_lo, w_hi, s_hi, x_hi]
        ops = (wq, scales, x2, wq, scales, x2)
    return pl.pallas_call(
        body,
        grid=(N // bn, steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, B), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, B), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, B), jnp.float32)],
        interpret=cfg.interpret,
    )(*ops)


# --------------------------------------------------------------------------
# registration
# --------------------------------------------------------------------------
def _example(small: bool = True, bits: int = 8, batch: int = 0):
    N, K = (128, 512) if small else (2048, 4096)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(ks[0], (N, K), jnp.float32)
    qt = quantize(w, bits=bits, group_size=128, axis=-1)
    if batch:
        x = jax.random.normal(ks[1], (batch, K), jnp.bfloat16)
    else:
        x = jax.random.normal(ks[1], (K,), jnp.bfloat16)
    return (qt.values, qt.scales, x), {}


def _qgemv_bytes(wq, s, x):
    K = x.shape[-1]
    B = x.shape[0] if len(x.shape) == 2 else 1
    return (numel(wq) * itemsize(wq) + numel(s) * itemsize(s)
            + B * K * itemsize(x) + B * wq.shape[0] * 4)


def _qgemv_streamed(wq, s, x):
    out = (x.shape[0], wq.shape[0]) if len(x.shape) == 2 else (wq.shape[0],)
    return [wq, s, x, jax.ShapeDtypeStruct(out, jnp.float32)]


_QSPACE = {"streams": (1, 2), "unroll": (1, 2),
           "block_n": (128, 256), "block_k": (256, 512)}


@troop_kernel(
    "qgemv",
    flops=lambda wq, s, x: 2.0 * wq.shape[0] * x.shape[0],
    bytes=_qgemv_bytes,
    streamed=_qgemv_streamed,
    space=_QSPACE,
    key_kwargs=("bits",),
    ref="qgemv", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg", "bits"))
def qgemv(wq, scales, x, cfg: TroopConfig = TroopConfig(), *, bits: int = 8):
    """Quantized GEMV: wq (N, K | K//2-packed) int8, scales (N, K//g),
    x (K,) -> y (N,) fp32.  ``bits`` is carried explicitly from the
    ``QuantizedTensor`` aux data (4 = nibble-packed along K)."""
    return _qgemv_2d(wq, scales, x.reshape(-1, 1), cfg, bits).reshape(-1)


@troop_kernel(
    "batched_qgemv",
    flops=lambda wq, s, xs: 2.0 * xs.shape[0] * wq.shape[0] * xs.shape[1],
    bytes=_qgemv_bytes,
    streamed=_qgemv_streamed,
    space=_QSPACE,
    key_kwargs=("bits",),
    ref="batched_qgemv",
    example=functools.partial(_example, batch=4))
@functools.partial(jax.jit, static_argnames=("cfg", "bits"))
def batched_qgemv(wq, scales, xs, cfg: TroopConfig = TroopConfig(), *,
                  bits: int = 8):
    """Small-batch decode projection: xs (B, K) -> (B, N) fp32.  The batch
    rides the lane dim of one kernel invocation — the weight stream (the
    roofline term) is unchanged from ``qgemv``."""
    return _qgemv_2d(wq, scales, xs.T, cfg, bits).T


# --------------------------------------------------------------------------
# MX microscaling kernels — block-exponent dequant in register
# --------------------------------------------------------------------------
# MX weights keep their stored (K, N) = (in_dim, out_dim) layout: the
# shared-exponent blocks run down K (axis -2, one uint8 E8M0 per 32 rows),
# so the kernels walk columns of the stored array directly — dequant is a
# nibble unpack + exp2 multiply between the DMA and the FMA stream, and no
# transpose ever materializes.  fp4 (uint8-packed e2m1) vs fp8
# (float8_e4m3fn) is discriminated statically by ``values.dtype``.

def _mx_bits(wq) -> int:
    return 4 if jnp.dtype(wq.dtype) == jnp.dtype(jnp.uint8) else 8


def _fp4_decode_block(w8):
    """(bkp, bn) uint8 packed e2m1 -> (2*bkp, bn) fp32 (unpack along K)."""
    lo = w8 & jnp.uint8(0x0F)
    hi = jnp.right_shift(w8, 4)
    c = jnp.stack([lo, hi], axis=1).reshape(2 * w8.shape[0], w8.shape[1])
    c = c.astype(jnp.int32)
    sign = 1.0 - 2.0 * (c >> 3).astype(jnp.float32)
    exp = ((c >> 1) & 3).astype(jnp.float32)
    man = (c & 1).astype(jnp.float32)
    mag = jnp.where(exp == 0, 0.5 * man,
                    (1.0 + 0.5 * man) * jnp.exp2(exp - 1.0))
    return sign * mag


def _mx_dequant_block(w_ref, s_ref, *, bits: int, g: int):
    """(bk[, packed], bn) codes + (bk//g, bn) E8M0 -> (bk, bn) fp32."""
    if bits == 4:
        w = _fp4_decode_block(w_ref[...])
    else:
        w = w_ref[...].astype(jnp.float32)
    bk, bn = w.shape
    s = jnp.exp2(s_ref[...].astype(jnp.float32) - E8M0_BIAS)
    return (w.reshape(bk // g, g, bn) * s[:, None, :]).reshape(bk, bn)


def _mx_kernel_1s(w_ref, s_ref, x_ref, o_ref, acc, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    w = _mx_dequant_block(w_ref, s_ref, bits=bits, g=g)
    acc[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _mx_kernel_2s(w0, s0, x0, w1, s1, x1, o_ref, acc, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    a = jnp.dot(x0[...].astype(jnp.float32),
                _mx_dequant_block(w0, s0, bits=bits, g=g),
                preferred_element_type=jnp.float32)
    b = jnp.dot(x1[...].astype(jnp.float32),
                _mx_dequant_block(w1, s1, bits=bits, g=g),
                preferred_element_type=jnp.float32)
    acc[...] += a + b

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _mx_tiles(N, K, g, pack, cfg: TroopConfig):
    """Shared tile solve for the MX kernels: (bn, bk, steps, streams)."""
    bn = min(cfg.block_n, N)
    while N % bn:
        bn //= 2
    streams = cfg.streams if (K // g) % 2 == 0 and cfg.streams == 2 else 1
    Kh = K // streams
    bk = max(min(cfg.block_k * cfg.unroll, Kh) // g * g, g)
    while Kh % bk:
        bk -= g
    assert bk % pack == 0, f"MX block_k {bk} not packable (pack={pack})"
    return bn, bk, Kh // bk, streams


def _mx_gemv_2d(wq, scales, x2, cfg: TroopConfig):
    """wq (K | K//2-packed, N), scales (K//g, N), x2 (B, K) -> (B, N)."""
    Ks, N = wq.shape
    B, K = x2.shape
    bits = _mx_bits(wq)
    pack = 2 if bits == 4 else 1
    g = K // scales.shape[0]
    bn, bk, steps, streams = _mx_tiles(N, K, g, pack, cfg)
    body = functools.partial(
        _mx_kernel_1s if streams == 1 else _mx_kernel_2s, bits=bits, g=g)

    w_lo = pl.BlockSpec((bk // pack, bn), lambda i, j: (j, i))
    w_hi = pl.BlockSpec((bk // pack, bn), lambda i, j, o=steps: (j + o, i))
    s_lo = pl.BlockSpec((bk // g, bn), lambda i, j: (j, i))
    s_hi = pl.BlockSpec((bk // g, bn), lambda i, j, o=steps: (j + o, i))
    x_lo = pl.BlockSpec((B, bk), lambda i, j: (0, j))
    x_hi = pl.BlockSpec((B, bk), lambda i, j, o=steps: (0, j + o))

    if streams == 1:
        in_specs, ops = [w_lo, s_lo, x_lo], (wq, scales, x2)
    else:
        in_specs = [w_lo, s_lo, x_lo, w_hi, s_hi, x_hi]
        ops = (wq, scales, x2, wq, scales, x2)
    return pl.pallas_call(
        body,
        grid=(N // bn, steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((B, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
        interpret=cfg.interpret,
    )(*ops)


def _mx_example(small: bool = True, elem: str = "fp4", batch: int = 0):
    N, K = (128, 512) if small else (2048, 4096)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    qt = quantize_mx(jax.random.normal(ks[0], (K, N), jnp.float32),
                     elem=elem, axis=-2)
    shape = (batch, K) if batch else (K,)
    x = jax.random.normal(ks[1], shape, jnp.bfloat16)
    return (qt.values, qt.scales, x), {}


def _mx_qgemv_bytes(wq, s, x):
    K = x.shape[-1]
    B = x.shape[0] if len(x.shape) == 2 else 1
    return (numel(wq) * itemsize(wq) + numel(s) * itemsize(s)
            + B * K * itemsize(x) + B * wq.shape[-1] * 4)


def _mx_qgemv_streamed(wq, s, x):
    out = (x.shape[0], wq.shape[-1]) if len(x.shape) == 2 else (wq.shape[-1],)
    return [wq, s, x, jax.ShapeDtypeStruct(out, jnp.float32)]


@troop_kernel(
    "mx_qgemv",
    flops=lambda wq, s, x: 2.0 * wq.shape[-1] * x.shape[-1],
    bytes=_mx_qgemv_bytes,
    streamed=_mx_qgemv_streamed,
    space=_QSPACE,
    ref="mx_qgemv", example=_mx_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def mx_qgemv(wq, scales, x, cfg: TroopConfig = TroopConfig()):
    """MX GEMV: wq (K | K//2-packed, N) fp4/fp8 codes, scales (K//g, N)
    E8M0, x (K,) -> y (N,) fp32.  Block-exponent dequant in register."""
    return _mx_gemv_2d(wq, scales, x.reshape(1, -1), cfg).reshape(-1)


@troop_kernel(
    "batched_mx_qgemv",
    flops=lambda wq, s, xs: 2.0 * xs.shape[0] * wq.shape[-1] * xs.shape[-1],
    bytes=_mx_qgemv_bytes,
    streamed=_mx_qgemv_streamed,
    space=_QSPACE,
    ref="batched_mx_qgemv",
    example=functools.partial(_mx_example, batch=4))
@functools.partial(jax.jit, static_argnames=("cfg",))
def batched_mx_qgemv(wq, scales, xs, cfg: TroopConfig = TroopConfig()):
    """Small-batch MX projection: xs (B, K) -> (B, N) fp32.  The batch
    rides the sublane dim; the weight stream is unchanged."""
    return _mx_gemv_2d(wq, scales, xs, cfg)


def _mx_swiglu_kernel(wg_ref, sg_ref, wu_ref, su_ref, x_ref, o_ref,
                      acc_g, acc_u, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[...].astype(jnp.float32)
    acc_g[...] += jnp.dot(x, _mx_dequant_block(wg_ref, sg_ref,
                                               bits=bits, g=g),
                          preferred_element_type=jnp.float32)
    acc_u[...] += jnp.dot(x, _mx_dequant_block(wu_ref, su_ref,
                                               bits=bits, g=g),
                          preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        a = acc_g[...]
        o_ref[...] = (a * jax.nn.sigmoid(a)
                      * acc_u[...]).astype(o_ref.dtype)


def _mx_swiglu_example(small: bool = True, elem: str = "fp4"):
    N, K = (128, 512) if small else (2048, 4096)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    qg = quantize_mx(jax.random.normal(ks[0], (K, N), jnp.float32),
                     elem=elem, axis=-2)
    qu = quantize_mx(jax.random.normal(ks[1], (K, N), jnp.float32),
                     elem=elem, axis=-2)
    x = jax.random.normal(ks[2], (K,), jnp.bfloat16)
    return (qg.values, qg.scales, qu.values, qu.scales, x), {}


def _mx_swiglu_bytes(wg, sg, wu, su, x):
    return (numel(wg) * itemsize(wg) + numel(sg) * itemsize(sg)
            + numel(wu) * itemsize(wu) + numel(su) * itemsize(su)
            + x.shape[-1] * itemsize(x) + wg.shape[-1] * 4)


def _mx_swiglu_streamed(wg, sg, wu, su, x):
    return [wg, sg, wu, su, x,
            jax.ShapeDtypeStruct((wg.shape[-1],), jnp.float32)]


@troop_kernel(
    "mx_qgemv_swiglu",
    flops=lambda wg, sg, wu, su, x: 4.0 * wg.shape[-1] * x.shape[-1],
    bytes=_mx_swiglu_bytes,
    streamed=_mx_swiglu_streamed,
    space={"streams": (1,), "unroll": (1, 2),
           "block_n": (128, 256), "block_k": (256, 512)},
    ref="mx_qgemv_swiglu", example=_mx_swiglu_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def mx_qgemv_swiglu(wg, sg, wu, su, x, cfg: TroopConfig = TroopConfig()):
    """Fused MX swiglu: silu(wg.T @ x) * (wu.T @ x) in one pass — the gate
    and up projections dequant-GEMV against the same resident x block and
    the silu·gate epilogue runs on the committed accumulators, halving the
    activation round-trips of the two-call form."""
    Ks, N = wg.shape
    K = x.shape[-1]
    bits = _mx_bits(wg)
    pack = 2 if bits == 4 else 1
    g = K // sg.shape[0]
    one = TroopConfig(streams=1, unroll=cfg.unroll, block_n=cfg.block_n,
                      block_k=cfg.block_k, interpret=cfg.interpret)
    bn, bk, steps, _ = _mx_tiles(N, K, g, pack, one)
    body = functools.partial(_mx_swiglu_kernel, bits=bits, g=g)
    w_spec = pl.BlockSpec((bk // pack, bn), lambda i, j: (j, i))
    s_spec = pl.BlockSpec((bk // g, bn), lambda i, j: (j, i))
    x_spec = pl.BlockSpec((1, bk), lambda i, j: (0, j))
    out = pl.pallas_call(
        body,
        grid=(N // bn, steps),
        in_specs=[w_spec, s_spec, w_spec, s_spec, x_spec],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32),
                        pltpu.VMEM((1, bn), jnp.float32)],
        interpret=cfg.interpret,
    )(wg, sg, wu, su, x.reshape(1, -1))
    return out.reshape(-1)


def _grouped_kernel(ids_ref, w_ref, s_ref, x_ref, o_ref, acc, *, bits, g):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    w = _mx_dequant_block(w_ref[0], s_ref[0], bits=bits, g=g)
    acc[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _grouped_example(small: bool = True, elem: str = "fp4"):
    E, topk = 4, 2
    N, K = (128, 512) if small else (1408, 2048)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    qt = quantize_mx(jax.random.normal(ks[0], (E, K, N), jnp.float32),
                     elem=elem, axis=-2)
    xs = jax.random.normal(ks[1], (topk, K), jnp.bfloat16)
    ids = jnp.array([1, 3], jnp.int32)[:topk]
    return (qt.values, qt.scales, xs, ids), {}


def _grouped_bytes(wq, s, xs, ids):
    topk, K = xs.shape
    # gathered traffic: top-k expert slices of the stacked weights/scales,
    # not the whole pool (the scalar-prefetched ids ride in SMEM for free)
    return (topk * wq.shape[1] * wq.shape[2] * itemsize(wq)
            + topk * s.shape[1] * s.shape[2] * itemsize(s)
            + topk * K * itemsize(xs) + topk * wq.shape[-1] * 4)


def _grouped_streamed(wq, s, xs, ids):
    topk = xs.shape[0]
    return [jax.ShapeDtypeStruct((topk,) + tuple(wq.shape[1:]), wq.dtype),
            jax.ShapeDtypeStruct((topk,) + tuple(s.shape[1:]), s.dtype),
            xs, jax.ShapeDtypeStruct((topk, wq.shape[-1]), jnp.float32)]


@troop_kernel(
    "grouped_expert_qgemv",
    flops=lambda wq, s, xs, ids: 2.0 * xs.shape[0] * wq.shape[-1]
    * xs.shape[-1],
    bytes=_grouped_bytes,
    streamed=_grouped_streamed,
    space={"streams": (1,), "unroll": (1, 2),
           "block_n": (128, 256), "block_k": (256, 512)},
    ref="grouped_expert_qgemv", example=_grouped_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def grouped_expert_qgemv(wq, scales, xs, expert_ids,
                         cfg: TroopConfig = TroopConfig()):
    """Grouped MX expert dispatch: wq (E, K | K//2-packed, N), scales
    (E, K//g, N) E8M0, xs (topk, K), expert_ids (topk,) int32 -> (topk, N).

    The router's selections are scalar-prefetched into SMEM and drive the
    weight BlockSpec index map, so each grid row DMAs exactly its chosen
    expert's tiles out of the stacked pool — no gather ever materializes a
    dequantized expert in HBM (same mechanism as the paged-attention
    block-table walk)."""
    E, Ks, N = wq.shape
    topk, K = xs.shape
    bits = _mx_bits(wq)
    pack = 2 if bits == 4 else 1
    g = K // scales.shape[1]
    one = TroopConfig(streams=1, unroll=cfg.unroll, block_n=cfg.block_n,
                      block_k=cfg.block_k, interpret=cfg.interpret)
    bn, bk, steps, _ = _mx_tiles(N, K, g, pack, one)
    body = functools.partial(_grouped_kernel, bits=bits, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(topk, N // bn, steps),
        in_specs=[
            pl.BlockSpec((1, bk // pack, bn),
                         lambda t, i, j, ids: (ids[t], j, i)),
            pl.BlockSpec((1, bk // g, bn),
                         lambda t, i, j, ids: (ids[t], j, i)),
            pl.BlockSpec((1, bk), lambda t, i, j, ids: (t, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda t, i, j, ids: (t, i)),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
    )
    return pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((topk, N), jnp.float32),
        interpret=cfg.interpret,
    )(expert_ids.astype(jnp.int32), wq, scales, xs)
