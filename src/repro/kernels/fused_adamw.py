"""Fused AdamW kernel — the paper's AXPY-class chain as ONE memory pass.

The reference optimizer evaluates ~10 elementwise HLO ops over param-sized
arrays (each a full HBM round-trip when not fused); this kernel streams
(p, g, mu, nu) once and writes (p', mu', nu') once: 7 streams total, the
roofline minimum.  ``streams=2`` splits every operand into contiguous
halves like the paper's decoupled VLSU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, numel, troop_kernel


def _example(small: bool = True):
    n = 4096 if small else 1 << 20
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    p = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    mu = jnp.zeros((n,))
    nu = jnp.zeros((n,))
    hp = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, bc1=0.1, bc2=0.1)
    return (p, g, mu, nu), hp


def _update(h_ref, p, g, mu, nu, po, muo, nuo):
    lr, b1, b2, eps, wd, bc1, bc2 = [h_ref[i] for i in range(7)]
    gf = g[...].astype(jnp.float32)
    m = b1 * mu[...] + (1 - b1) * gf
    n = b2 * nu[...] + (1 - b2) * gf * gf
    upd = (m / bc1) / (jnp.sqrt(n / bc2) + eps)
    pf = p[...].astype(jnp.float32)
    pf = pf - lr * (upd + wd * pf)
    po[...] = pf.astype(po.dtype)
    muo[...] = m
    nuo[...] = n


def _kernel_2s(h_ref, p0, p1, g0, g1, mu0, mu1, nu0, nu1,
               po0, po1, muo0, muo1, nuo0, nuo1):
    _update(h_ref, p0, g0, mu0, nu0, po0, muo0, nuo0)
    _update(h_ref, p1, g1, mu1, nu1, po1, muo1, nuo1)


@troop_kernel(
    "fused_adamw",
    flops=lambda p, g, mu, nu: 12.0 * numel(p),
    # one pass: read (p, g, mu, nu), write (p', mu', nu'); moments fp32
    bytes=lambda p, g, mu, nu: numel(p) * (2 * itemsize(p) + itemsize(g)
                                           + 4 * 4),
    streamed=lambda p, g, mu, nu: [p, p, g] + [
        jax.ShapeDtypeStruct(p.shape, jnp.float32)] * 4,
    #   p in + p' out + g in + (mu, nu) fp32 in/out
    space={"streams": (1, 2), "unroll": (1, 2), "block_k": (256, 512, 1024)},
    ref="fused_adamw", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def fused_adamw(p, g, mu, nu, *, lr, b1, b2, eps, wd, bc1, bc2,
                cfg: TroopConfig = TroopConfig()):
    """Flat-or-shaped arrays; returns (p', mu', nu')."""
    shape, dtype = p.shape, p.dtype
    n = p.size
    lanes = 128
    pad = (-n) % lanes
    def flat(a, dt):
        a = a.reshape(-1).astype(dt)
        if pad:
            a = jnp.concatenate([a, jnp.zeros((pad,), dt)])
        return a.reshape(-1, lanes)
    pf, gf = flat(p, dtype), flat(g, g.dtype)
    muf, nuf = flat(mu, jnp.float32), flat(nu, jnp.float32)
    rows = pf.shape[0]
    h = jnp.stack([jnp.asarray(v, jnp.float32) for v in
                   (lr, b1, b2, eps, wd, bc1, bc2)])

    br = max(min(cfg.block_k * cfg.unroll // lanes, rows // cfg.streams), 1)
    if cfg.streams == 1 or rows < 2:
        while rows % br:
            br //= 2
        blk = lambda: pl.BlockSpec((br, lanes), lambda j: (j, 0))
        outs = pl.pallas_call(
            functools.partial(_update),
            grid=(rows // br,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                      blk(), blk(), blk(), blk()],
            out_specs=[blk(), blk(), blk()],
            out_shape=[jax.ShapeDtypeStruct((rows, lanes), dtype),
                       jax.ShapeDtypeStruct((rows, lanes), jnp.float32),
                       jax.ShapeDtypeStruct((rows, lanes), jnp.float32)],
            interpret=cfg.interpret,
        )(h, pf, gf, muf, nuf)
        po, muo, nuo = outs
    else:
        half = rows // 2
        while half % br:
            br //= 2
        steps = half // br
        lo = lambda: pl.BlockSpec((br, lanes), lambda j: (j, 0))
        hi = lambda: pl.BlockSpec((br, lanes), lambda j, o=steps: (j + o, 0))
        sh = lambda dt: jax.ShapeDtypeStruct((half, lanes), dt)
        outs = pl.pallas_call(
            _kernel_2s,
            grid=(steps,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                      lo(), hi(), lo(), hi(), lo(), hi(), lo(), hi()],
            out_specs=[lo(), lo(), lo(), lo(), lo(), lo()],
            out_shape=[sh(dtype), sh(dtype), sh(jnp.float32),
                       sh(jnp.float32), sh(jnp.float32), sh(jnp.float32)],
            interpret=cfg.interpret,
        )(h, pf, pf, gf, gf, muf, muf, nuf, nuf)
        po = jnp.concatenate([outs[0], outs[1]])
        muo = jnp.concatenate([outs[2], outs[3]])
        nuo = jnp.concatenate([outs[4], outs[5]])

    unflat = lambda a, dt: a.reshape(-1)[:n].reshape(shape).astype(dt)
    return unflat(po, dtype), unflat(muo, jnp.float32), unflat(nuo, jnp.float32)
