"""Continuous-batching serving engine: end-to-end + splice correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step


def setup():
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def test_engine_serves_batched_requests():
    cfg, model, params = setup()
    eng = ServingEngine(
        model, slots=4, cache_len=32,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + i) % 63 + 1,
                    max_new_tokens=6) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)


def test_engine_output_matches_sequential_decode():
    """Greedy outputs under continuous batching == single-request decode."""
    cfg, model, params = setup()
    prompt = np.asarray([3, 14, 15, 9, 2, 6], np.int32)

    # oracle: full forward + greedy loop (no engine)
    toks = list(prompt)
    for _ in range(4):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    want = toks[len(prompt):]

    eng = ServingEngine(
        model, slots=2, cache_len=32,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    req = Request(rid=0, prompt=prompt, max_new_tokens=4)
    # a competing request exercises multi-slot interference
    other = Request(rid=1, prompt=np.asarray([7, 7, 7], np.int32),
                    max_new_tokens=4)
    eng.submit(req)
    eng.submit(other)
    eng.run_until_drained()
    assert req.out == want


def test_slots_are_reused():
    cfg, model, params = setup()
    eng = ServingEngine(
        model, slots=1, cache_len=24,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.asarray([1 + i, 2, 3], np.int32),
                           max_new_tokens=3))
    eng.run_until_drained()
    assert eng.steps <= 3 * 3 + 3


def test_encdec_serving_with_frontend_stub():
    """Whisper-style serving: frontend stub supplied via prefill_extras."""
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("whisper-base"))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    extras = lambda req: {"frontend": 0.1 * jnp.ones(
        (1, cfg.cross_attention_len, cfg.d_model), jnp.bfloat16)}
    eng = ServingEngine(
        model, slots=2, cache_len=32,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params,
        prefill_extras=extras)
    reqs = [Request(rid=i, prompt=np.arange(1, 4 + i), max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done and len(r.out) == 4 for r in reqs)


def test_serving_with_int8_kv_cache():
    """§Perf A4 in the engine: int8 caches serve correctly end-to-end."""
    cfg, model_bf16, params = setup()
    model = build_model(cfg, RuntimeConfig(remat="none", cache_dtype="int8"))
    eng = ServingEngine(
        model, slots=2, cache_len=32,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    req = Request(rid=0, prompt=np.asarray([3, 14, 15, 9], np.int32),
                  max_new_tokens=5)
    eng.submit(req)
    eng.run_until_drained()
    assert req.done and len(req.out) == 5
