"""Quantization benchmark -> table + BENCH_quant.json.

Quantization moves the roofline itself (DESIGN.md §5): at OI ~= 1 the
bound is bytes/BW, so the headline numbers here are *modeled bytes* ratios
(exact, from the registry's audited cost models — int8/int4 values + scale
traffic vs the bf16 stream) next to measured interpret-mode wall times and
fraction-of-roofline, plus the accuracy cost vs the fp32 oracle:

  * qgemv int8 / int4  vs gemv bf16      (the decode projection GEMV)
  * mx_qgemv mx4 / fp8 + fused swiglu + grouped expert dispatch
    (MX microscaling, DESIGN.md §11: fp4/fp8 values + E8M0 exponents)
  * paged_decode_attention_int8 vs bf16  (the paged decode cache stream)
  * qwen2-moe engines bf16 / int8 / mx4 tok/s, modeled joules/token,
    and the byte-exact quantized-MoE decode-step dispatch audit

Acceptance self-checks (raise on violation): qgemv-int8 modeled bytes
<= 0.6x the bf16 gemv bytes at the same shape, mx4 <= 0.28x and
fp8 <= 0.55x, int8 outputs within rtol ~2e-2 of the fp32 oracle (int4
documented at ~2e-1, mx4 ~0.35, fp8 ~0.1), modeled joules/token strictly
falling mx4 < int8 < bf16, and the mx4/fp8 MoE audits must match.

    PYTHONPATH=src python benchmarks/quant_bench.py --fast

Interpret-mode wall times on CPU are NOT TPU performance (DESIGN.md §3);
the modeled-bytes ratios are exact on any backend.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

INT8_RTOL = 2e-2       # documented tolerance vs the fp32 oracle
INT4_RTOL = 2e-1


def _measure(fn, iters):
    import jax
    out = fn()
    jax.tree.map(lambda x: x.block_until_ready(), out)
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.tree.map(lambda x: x.block_until_ready(), out)
        best = min(best, time.perf_counter() - t0)
    return best


def bench_qgemv(*, N, K, iters):
    import jax
    import jax.numpy as jnp
    import numpy as np
    import repro.kernels as Kn
    from repro.kernels import ref as R
    from repro.quant import quantize
    from repro.tune import REGISTRY
    from repro.tune.cache import get_tuned
    from repro.tune.search import roofline_time

    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(ks[0], (N, K), jnp.float32)
    x = jax.random.normal(ks[1], (K,), jnp.bfloat16)
    oracle = np.asarray(R.gemv(w, x.astype(jnp.float32)))
    scale = float(np.max(np.abs(oracle)))

    wb = w.astype(jnp.bfloat16)
    spec_bf = REGISTRY["gemv"]
    bf_bytes = spec_bf.bytes(wb, x)
    cfg = get_tuned("gemv", wb, x)
    t_bf = _measure(lambda: Kn.gemv(wb, x, cfg), iters)
    rows = [{
        "kernel": "gemv", "dtype": "bfloat16", "shape": f"N={N} K={K}",
        "modeled_bytes": bf_bytes, "bytes_ratio_vs_bf16": 1.0,
        "measured_us": t_bf * 1e6,
        "roofline_us": roofline_time(spec_bf, (wb, x)) * 1e6,
        "fraction_of_roofline": roofline_time(spec_bf, (wb, x)) / t_bf,
        "max_rel_err_vs_fp32": float(
            np.max(np.abs(np.asarray(Kn.gemv(wb, x, cfg)) - oracle))
            / scale),
    }]
    spec_q = REGISTRY["qgemv"]
    for bits, rtol in ((8, INT8_RTOL), (4, INT4_RTOL)):
        qt = quantize(w, bits=bits, group_size=128, axis=-1)
        args = (qt.values, qt.scales, x)
        q_bytes = spec_q.bytes(*args)
        qcfg = get_tuned("qgemv", *args, variant_kwargs={"bits": bits})
        t = _measure(lambda: Kn.qgemv(*args, qcfg, bits=bits), iters)
        y = np.asarray(Kn.qgemv(*args, qcfg, bits=bits))
        err = float(np.max(np.abs(y - oracle)) / scale)
        ratio = q_bytes / bf_bytes
        rows.append({
            "kernel": "qgemv", "dtype": f"int{bits}",
            "shape": f"N={N} K={K} g=128",
            "modeled_bytes": q_bytes, "bytes_ratio_vs_bf16": ratio,
            "measured_us": t * 1e6,
            "roofline_us": roofline_time(spec_q, args) * 1e6,
            "fraction_of_roofline": roofline_time(spec_q, args) / t,
            "max_rel_err_vs_fp32": err,
            "speedup_vs_bf16": t_bf / t,
        })
        if bits == 8:
            assert ratio <= 0.6, \
                f"qgemv int8 modeled bytes {ratio:.3f}x bf16 (want <= 0.6)"
            assert err <= INT8_RTOL, \
                f"qgemv int8 err {err:.4f} vs fp32 oracle (want <= {INT8_RTOL})"
        else:
            assert err <= INT4_RTOL, err
    return rows


MX4_BYTES_RATIO = 0.28      # acceptance: mx4 stream vs the bf16 stream
FP8_BYTES_RATIO = 0.55      # acceptance: fp8 stream vs the bf16 stream


def bench_mx_qgemv(*, N, K, iters):
    """MX microscaling decode GEMV (DESIGN.md §11): fp4/fp8 values +
    E8M0 block exponents vs the bf16 stream, plus the fused swiglu and
    the grouped expert dispatch."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    import repro.kernels as Kn
    from repro.kernels import ref as R
    from repro.quant import quantize_mx
    from repro.tune import REGISTRY
    from repro.tune.cache import get_tuned
    from repro.tune.search import roofline_time

    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(ks[0], (K, N), jnp.float32)   # stored (in, out)
    x = jax.random.normal(ks[1], (K,), jnp.float32)
    oracle = np.asarray(R.gemv(w.T, x))
    scale = float(np.max(np.abs(oracle)))

    wb, xb = w.T.astype(jnp.bfloat16), x.astype(jnp.bfloat16)
    bf_bytes = REGISTRY["gemv"].bytes(wb, xb)
    t_bf = _measure(lambda: Kn.gemv(wb, xb, get_tuned("gemv", wb, xb)),
                    iters)

    rows = []
    spec = REGISTRY["mx_qgemv"]
    for elem, gate in (("fp4", MX4_BYTES_RATIO), ("fp8", FP8_BYTES_RATIO)):
        qt = quantize_mx(w, elem=elem)
        args = (qt.values, qt.scales, x)
        q_bytes = spec.bytes(*args)
        qcfg = get_tuned("mx_qgemv", *args)
        t = _measure(lambda: Kn.mx_qgemv(*args, qcfg), iters)
        err = float(np.max(np.abs(np.asarray(Kn.mx_qgemv(*args, qcfg))
                                  - oracle)) / scale)
        ratio = q_bytes / bf_bytes
        tag = "mx4" if elem == "fp4" else "fp8"
        rows.append({
            "kernel": "mx_qgemv", "dtype": tag,
            "shape": f"N={N} K={K} block=32",
            "modeled_bytes": q_bytes, "bytes_ratio_vs_bf16": ratio,
            "measured_us": t * 1e6,
            "roofline_us": roofline_time(spec, args) * 1e6,
            "fraction_of_roofline": roofline_time(spec, args) / t,
            "max_rel_err_vs_fp32": err,
            "speedup_vs_bf16": t_bf / t,
        })
        assert ratio <= gate, \
            f"mx_qgemv {tag} modeled bytes {ratio:.3f}x bf16 (want <= {gate})"
        assert err <= (0.35 if elem == "fp4" else 0.10), \
            f"mx_qgemv {tag} err {err:.4f} vs fp32 oracle"

    # fused swiglu: two mx4 weight streams, one activation stream
    f = N
    kg, ku = jax.random.split(jax.random.PRNGKey(1))
    qg = quantize_mx(jax.random.normal(kg, (K, f), jnp.float32), elem="fp4")
    qu = quantize_mx(jax.random.normal(ku, (K, f), jnp.float32), elem="fp4")
    spec_s = REGISTRY["mx_qgemv_swiglu"]
    args_s = (qg.values, qg.scales, qu.values, qu.scales, x)
    t_s = _measure(lambda: Kn.mx_qgemv_swiglu(*args_s), iters)
    rows.append({
        "kernel": "mx_qgemv_swiglu", "dtype": "mx4",
        "shape": f"d={K} d_ff={f} block=32",
        "modeled_bytes": spec_s.bytes(*args_s),
        "bytes_ratio_vs_bf16": spec_s.bytes(*args_s) / (2 * bf_bytes),
        "measured_us": t_s * 1e6,
        "fraction_of_roofline": roofline_time(spec_s, args_s) / t_s,
    })

    # grouped expert dispatch: topk gathered stacks per router selection
    E, topk = 8, 2
    we = jax.random.normal(jax.random.PRNGKey(2), (E, K, N), jnp.float32)
    qe = quantize_mx(we, elem="fp4")
    xs = jnp.broadcast_to(x, (topk, K))
    ids = jnp.asarray([1, 5], jnp.int32)
    spec_g = REGISTRY["grouped_expert_qgemv"]
    args_g = (qe.values, qe.scales, xs, ids)
    t_g = _measure(lambda: Kn.grouped_expert_qgemv(*args_g), iters)
    got = np.asarray(Kn.grouped_expert_qgemv(*args_g))
    want = np.asarray(R.grouped_expert_qgemv(*args_g))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    rows.append({
        "kernel": "grouped_expert_qgemv", "dtype": "mx4",
        "shape": f"E={E} topk={topk} K={K} N={N}",
        "modeled_bytes": spec_g.bytes(*args_g),
        "bytes_ratio_vs_bf16": spec_g.bytes(*args_g) / (topk * bf_bytes),
        "measured_us": t_g * 1e6,
        "fraction_of_roofline": roofline_time(spec_g, args_g) / t_g,
    })
    return rows


def bench_engine_moe(*, slots, cache_len, requests, max_new):
    """Quantized-expert serving: bf16 vs int8 vs mx4 MoE engine tok/s,
    plus the modeled joules/token rows (the roofline move in energy)."""
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.obs.energy import engine_energy_row
    from repro.serve import EngineConfig, build_engine
    from repro.serve.scheduler import Request

    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    out = []
    for tag, qw in (("moe-bf16", "none"), ("moe-int8", "int8"),
                    ("moe-mx4", "mx4")):
        eng = build_engine(cfg, EngineConfig(
            slots=slots, cache_len=cache_len, backend="paged",
            quantize_weights=qw))
        rng = np.random.default_rng(0)
        for i in range(requests):
            eng.submit(Request(
                rid=i, prompt=rng.integers(1, min(cfg.vocab_size, 500),
                                           int(rng.integers(4, 12))),
                max_new_tokens=max_new))
        t0 = time.perf_counter()
        finished = eng.run_until_drained()
        m = eng.metrics()
        m.update({"engine": tag, "quantize_weights": qw,
                  "wall_s": time.perf_counter() - t0,
                  "all_finished": len(finished) == requests})
        assert m["all_finished"], f"{tag}: engine did not drain"
        out.append(m)

    energy = []
    for weights in ("bfloat16", "int8", "mx4", "fp8"):
        row = engine_energy_row(cfg, slots=slots, cache_len=cache_len,
                                weights=weights)
        row.pop("per_kernel", None)
        energy.append(row)
    j = {r["weights"]: r["joules_per_token"] for r in energy}
    assert j["mx4"] < j["int8"] < j["bfloat16"], \
        f"modeled joules/token must fall with the weight stream: {j}"

    # the acceptance invariant: a quantized-MoE decode step audits
    # byte-exact (measured kernel multiset == decode_step_account)
    from repro import obs
    from repro.models import RuntimeConfig, build_model
    audits = []
    for fmt in ("mx4", "fp8"):
        model = build_model(cfg, RuntimeConfig(remat="none",
                                               quantize_weights=fmt))
        a = obs.audit_decode_step(model, cache_len=cache_len)
        assert a.ok, a.report()
        audits.append({"arch": a.arch, "weights": fmt,
                       "kv_dtype": a.kv_dtype, "match": a.ok,
                       "dispatches": a.dispatches,
                       "modeled_bytes_measured": int(a.measured_bytes),
                       "modeled_bytes_expected": int(a.expected_bytes)})
    return out, energy, audits


def bench_paged_decode(*, B, S, page, iters):
    import jax
    import jax.numpy as jnp
    import numpy as np
    import repro.kernels as Kn
    from repro.quant import quantize_kv
    from repro.tune import REGISTRY
    from repro.tune.cache import get_tuned
    from repro.tune.search import roofline_time

    KV, H, hd = 2, 4, 64
    nblk = -(-S // page)
    P = B * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), jnp.float32)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), jnp.float32)
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)
    length = jnp.full((B,), S - 1, jnp.int32)

    kb, vb = k_pool.astype(jnp.bfloat16), v_pool.astype(jnp.bfloat16)
    spec_bf = REGISTRY["paged_decode_attention"]
    args_bf = (q, kb, vb, bt, length)
    cfg = get_tuned(*(("paged_decode_attention",) + args_bf))
    t_bf = _measure(lambda: Kn.paged_decode_attention(*args_bf, cfg), iters)
    bf_bytes = spec_bf.bytes(*args_bf)
    oracle = np.asarray(
        Kn.paged_decode_attention(*args_bf, cfg), np.float32)
    scale = float(np.max(np.abs(oracle)))

    k8, ksc = quantize_kv(k_pool)
    v8, vsc = quantize_kv(v_pool)
    spec_q = REGISTRY["paged_decode_attention_int8"]
    args_q = (q, k8, ksc, v8, vsc, bt, length)
    qcfg = get_tuned(*(("paged_decode_attention_int8",) + args_q))
    t_q = _measure(
        lambda: Kn.paged_decode_attention_int8(*args_q, qcfg), iters)
    q_bytes = spec_q.bytes(*args_q)
    err = float(np.max(np.abs(np.asarray(
        Kn.paged_decode_attention_int8(*args_q, qcfg), np.float32)
        - oracle)) / scale)
    rows = [
        {"kernel": "paged_decode_attention", "dtype": "bfloat16",
         "shape": f"B={B} S={S} page={page} KV={KV} hd={hd}",
         "modeled_bytes": bf_bytes, "bytes_ratio_vs_bf16": 1.0,
         "measured_us": t_bf * 1e6,
         "fraction_of_roofline": roofline_time(spec_bf, args_bf) / t_bf},
        {"kernel": "paged_decode_attention_int8", "dtype": "int8",
         "shape": f"B={B} S={S} page={page} KV={KV} hd={hd}",
         "modeled_bytes": q_bytes,
         "bytes_ratio_vs_bf16": q_bytes / bf_bytes,
         "measured_us": t_q * 1e6,
         "fraction_of_roofline": roofline_time(spec_q, args_q) / t_q,
         "max_rel_err_vs_bf16": err,
         "speedup_vs_bf16": t_bf / t_q},
    ]
    assert q_bytes / bf_bytes <= 0.6, "int8 paged stream not under 0.6x"
    return rows


def bench_engine_int8(*, slots, cache_len, requests, max_new):
    """End-to-end: bf16-paged vs int8-paged engine tokens/s (greedy)."""
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.serve import EngineConfig, build_engine
    from repro.serve.scheduler import Request

    cfg = reduced(get_config("qwen1.5-0.5b"))
    out = []
    for tag, kv in (("paged-bf16", ""), ("paged-int8", "int8")):
        eng = build_engine(cfg, EngineConfig(
            slots=slots, cache_len=cache_len, backend="paged",
            kv_cache_dtype=kv))
        rng = np.random.default_rng(0)
        for i in range(requests):
            eng.submit(Request(
                rid=i, prompt=rng.integers(1, min(cfg.vocab_size, 1000),
                                           int(rng.integers(4, 16))),
                max_new_tokens=max_new))
        t0 = time.perf_counter()
        finished = eng.run_until_drained()
        m = eng.metrics()
        m.update({"engine": tag, "wall_s": time.perf_counter() - t0,
                  "all_finished": len(finished) == requests})
        out.append(m)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="small shapes / fewer iterations (CI smoke)")
    ap.add_argument("--out", default="BENCH_quant.json")
    args = ap.parse_args(argv)

    import jax
    iters = 1 if args.fast else 3
    N, K = (256, 1024) if args.fast else (2048, 4096)
    S, page = (128, 32) if args.fast else (1024, 32)

    gemv_rows = bench_qgemv(N=N, K=K, iters=iters)
    mx_rows = bench_mx_qgemv(N=N, K=K, iters=iters)
    decode_rows = bench_paged_decode(B=4, S=S, page=page, iters=iters)
    engines = bench_engine_int8(slots=4, cache_len=64,
                                requests=4 if args.fast else 8,
                                max_new=4 if args.fast else 12)
    moe_engines, energy_rows, audit_rows = bench_engine_moe(
        slots=3, cache_len=64, requests=3 if args.fast else 6,
        max_new=4 if args.fast else 8)

    hdr = (f"{'kernel':<28}{'dtype':<10}{'bytes':>12}{'ratio':>8}"
           f"{'meas_us':>12}{'frac-roof':>12}{'rel-err':>10}")
    print(hdr)
    print("-" * len(hdr))
    for r in gemv_rows + mx_rows + decode_rows:
        err = r.get("max_rel_err_vs_fp32", r.get("max_rel_err_vs_bf16"))
        print(f"{r['kernel']:<28}{r['dtype']:<10}"
              f"{r['modeled_bytes']:>12.0f}"
              f"{r['bytes_ratio_vs_bf16']:>8.3f}"
              f"{r['measured_us']:>12.1f}"
              f"{r['fraction_of_roofline']:>12.3e}"
              + (f"{err:>10.4f}" if err is not None else ""))
    for m in engines:
        print(f"{m['engine']:<16} {m['decode_steps']:>4} steps  "
              f"{m['tokens_per_s']:>8.2f} tok/s  kv={m.get('kv_dtype')}")
    for m in moe_engines:
        print(f"{m['engine']:<16} {m['decode_steps']:>4} steps  "
              f"{m['tokens_per_s']:>8.2f} tok/s  "
              f"weights={m['quantize_weights']}")
    for r in energy_rows:
        print(f"energy/{r['weights']:<9} "
              f"{r['bytes_per_token']:>12,d} B/tok  "
              f"{r['joules_per_token'] * 1e3:>8.4f} mJ/tok")
    for a in audit_rows:
        print(f"audit/{a['weights']:<9} match={a['match']}  "
              f"{a['dispatches']} dispatches  "
              f"{a['modeled_bytes_measured']:,} B")

    payload = {
        "backend": jax.default_backend(),
        "interpret_mode": True,
        "int8_rtol": INT8_RTOL, "int4_rtol": INT4_RTOL,
        "mx4_bytes_ratio": MX4_BYTES_RATIO,
        "fp8_bytes_ratio": FP8_BYTES_RATIO,
        "qgemv": gemv_rows,
        "mx": mx_rows,
        "paged_decode": decode_rows,
        "engines": engines,
        "moe_engines": moe_engines,
        "energy": energy_rows,
        "audit": audit_rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
