"""Serving steps: prefill_step / serve_step (single-token decode).

serve_step is the paper's workload: one new token against a KV cache — every
matmul a GEMV-class memory-bound op.  Greedy sampling keeps the step a pure
function (temperature sampling threads an rng key).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch)
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return prefill_step


def make_serve_step(model, *, temperature: float = 0.0):
    def serve_step(params, batch, caches):
        logits, caches = model.decode_step(params, batch, caches)
        if temperature > 0:
            key = jax.random.fold_in(jax.random.PRNGKey(0), batch["pos"][0])
            next_tok = jax.random.categorical(
                key, logits[:, -1, :] / temperature)[:, None].astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return serve_step
