"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.troop import BASELINE, TROOP, TroopConfig
from repro.kernels import ops as K
from repro.kernels import ref as R

CFGS = [BASELINE, TROOP,
        TroopConfig(streams=2, unroll=1, block_n=128, block_k=256)]


def tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("N,K_", [(256, 1024), (512, 4096), (128, 512)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_gemv(N, K_, dt):
    w = jax.random.normal(jax.random.PRNGKey(0), (N, K_), dt)
    x = jax.random.normal(jax.random.PRNGKey(1), (K_,), dt)
    want = R.gemv(w, x)
    for cfg in CFGS:
        np.testing.assert_allclose(K.gemv(w, x, cfg), want, **tol(dt))


@pytest.mark.parametrize("K_", [4096, 32768])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_dotp(K_, dt):
    x = jax.random.normal(jax.random.PRNGKey(0), (K_,), dt)
    y = jax.random.normal(jax.random.PRNGKey(1), (K_,), dt)
    want = R.dotp(x, y)
    for cfg in CFGS:
        np.testing.assert_allclose(K.dotp(x, y, cfg), want,
                                   rtol=5e-2 if dt == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("K_", [4096, 65536])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_axpy(K_, dt):
    x = jax.random.normal(jax.random.PRNGKey(0), (K_,), dt)
    y = jax.random.normal(jax.random.PRNGKey(1), (K_,), dt)
    want = np.asarray(R.axpy(1.7, x, y), np.float32)
    for cfg in CFGS:
        got = np.asarray(K.axpy(1.7, x, y, cfg), np.float32)
        np.testing.assert_allclose(got, want, **tol(dt))


@pytest.mark.parametrize("T,d", [(64, 512), (128, 1024), (8, 256)])
def test_rmsnorm(T, d):
    x = jax.random.normal(jax.random.PRNGKey(0), (T, d), jnp.bfloat16)
    s = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    got = np.asarray(K.rmsnorm(x, s), np.float32)
    want = np.asarray(R.rmsnorm(x, s), np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("n", [1000, 4096, 131072])
def test_fused_adamw(n):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    mu = 0.1 * jax.random.normal(ks[2], (n,))
    nu = jnp.abs(0.1 * jax.random.normal(ks[3], (n,)))
    hp = dict(lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, bc1=0.2, bc2=0.1)
    want = R.fused_adamw(p, g, mu, nu, **hp)
    for cfg in (BASELINE, TROOP):
        got = K.fused_adamw(p, g, mu, nu, **hp, cfg=cfg)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,H,KV,hd,S", [
    (2, 8, 8, 64, 1024), (2, 8, 2, 64, 2048), (1, 16, 4, 128, 512),
    (4, 4, 4, 32, 256),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, KV, hd, S, dt):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dt)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dt)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dt)
    length = jnp.asarray([(S // 2 + 17 * b) % S + 1 for b in range(B)],
                         jnp.int32)
    want = np.asarray(R.decode_attention(q, k, v, length), np.float32)
    for cfg in (BASELINE, TROOP):
        got = np.asarray(K.decode_attention(q, k, v, length, cfg), np.float32)
        np.testing.assert_allclose(got, want, **tol(dt))


@pytest.mark.parametrize("B,H,KV,hd,page,nblk", [
    (2, 8, 8, 64, 16, 8), (2, 8, 2, 64, 32, 4), (1, 16, 4, 128, 16, 3),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, H, KV, hd, page, nblk, dt):
    """Block-table gather == dense flash-decode (incl. odd-nblk fallback)."""
    P = 1 + B * nblk
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dt)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), dt)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), dt)
    # physically scattered, logically contiguous tables + ragged lengths
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)
    S = nblk * page
    length = jnp.asarray([(S // 2 + 17 * b) % S + 1 for b in range(B)],
                         jnp.int32)
    want = np.asarray(R.paged_decode_attention(q, k_pool, v_pool, bt, length),
                      np.float32)
    for cfg in (BASELINE, TROOP):
        got = np.asarray(
            K.paged_decode_attention(q, k_pool, v_pool, bt, length, cfg),
            np.float32)
        np.testing.assert_allclose(got, want, **tol(dt))
    # paged result == dense kernel over the gathered logical view
    k_d = k_pool[bt].reshape(B, S, KV, hd)
    v_d = v_pool[bt].reshape(B, S, KV, hd)
    dense = np.asarray(K.decode_attention(q, k_d, v_d, length, TROOP),
                       np.float32)
    np.testing.assert_allclose(dense, want, **tol(dt))


@pytest.mark.parametrize("B,C,H,KV,hd,page,nblk", [
    (2, 16, 8, 8, 64, 16, 8), (2, 32, 8, 2, 64, 32, 4),
    (1, 16, 16, 4, 128, 16, 3),
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_prefill_attention_paged(B, C, H, KV, hd, page, nblk, dt):
    """Chunked-prefill slab over scattered pages == causal oracle with a
    query offset (incl. odd-nblk one-stream fallback and pad rows)."""
    P = 1 + B * nblk
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, C, H, hd), dt)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), dt)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), dt)
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)
    # slab b enters mid-sequence (prefix-cache hit) with a ragged tail
    q_offset = jnp.asarray([5 * b for b in range(B)], jnp.int32)
    valid = jnp.asarray([C - 3 * b for b in range(B)], jnp.int32)
    length = q_offset + valid
    want = np.asarray(
        R.prefill_attention_paged(q, k_pool, v_pool, bt, q_offset, length),
        np.float32)
    for cfg in (BASELINE, TROOP):
        got = np.asarray(
            K.prefill_attention_paged(q, k_pool, v_pool, bt, q_offset,
                                      length, cfg), np.float32)
        for b in range(B):                 # pad rows are garbage by contract
            v = int(valid[b])
            np.testing.assert_allclose(got[b, :v], want[b, :v], **tol(dt))


@pytest.mark.parametrize("B,T,H,KV,hd,S", [
    (2, 256, 8, 8, 64, 256), (1, 512, 8, 2, 64, 512),
])
def test_flash_attention(B, T, H, KV, hd, S):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    want = R.flash_attention(q, k, v, causal=True)
    for cfg in (BASELINE, TROOP):
        got = K.flash_attention(q, k, v, True, cfg)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,T,H,hd", [(2, 64, 4, 32), (1, 128, 2, 64)])
def test_wkv6(B, T, H, hd):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hd))))
    u = 0.5 * jnp.ones((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    want_y, want_s = R.wkv6(r, k, v, w, u, s0)
    for cfg in (BASELINE, TROOP):
        y, s = K.wkv6(r, k, v, w, u, s0, cfg)
        np.testing.assert_allclose(y, want_y, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s, want_s, rtol=1e-4, atol=1e-4)


def test_wkv6_with_carried_state():
    """Nonzero initial state folds in exactly (decode chaining path)."""
    B, T, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hd))))
    u = 0.3 * jnp.ones((H, hd))
    s0 = jax.random.normal(ks[4], (B, H, hd, hd))
    want_y, want_s = R.wkv6(r, k, v, w, u, s0)
    y, s = K.wkv6_with_state(r, k, v, w, u, s0)
    np.testing.assert_allclose(y, want_y, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s, want_s, rtol=1e-4, atol=1e-4)


def test_decode_stats_lse_combine_split_s():
    """Split-S partials combine to the full result (SP decode invariant)."""
    B, H, KV, hd, S = 2, 8, 4, 64, 1024
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    length = jnp.asarray([700, 1024], jnp.int32)
    want = np.asarray(R.decode_attention(q, k, v, length), np.float32)
    n_shards = 4
    Sl = S // n_shards
    partials = []
    for i in range(n_shards):
        partials.append(K.decode_attention_stats(
            q, k[:, i * Sl:(i + 1) * Sl], v[:, i * Sl:(i + 1) * Sl],
            length, TROOP, s_offset=i * Sl))
    got = np.asarray(K.lse_combine(partials), np.float32).reshape(B, H, hd)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,T,di,ds", [(1, 64, 128, 16), (2, 32, 64, 8)])
def test_mamba_scan(b, T, di, ds):
    from repro.kernels.mamba_scan import mamba_scan
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, di)))
    Bm = jax.random.normal(ks[2], (b, T, ds))
    Cm = jax.random.normal(ks[3], (b, T, ds))
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)))
    D = jnp.ones((di,))
    s0 = jnp.zeros((b, di, ds))
    want_y, want_s = R.mamba_scan(x, dt, Bm, Cm, A, D, s0)
    for cfg in (BASELINE, TROOP):
        y, s = mamba_scan(x, dt, Bm, Cm, A, D, s0, cfg)
        np.testing.assert_allclose(y, want_y, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s, want_s, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,H,KV,hd,S", [(2, 8, 4, 64, 1024),
                                         (1, 16, 8, 128, 512)])
def test_decode_attention_int8(B, H, KV, hd, S):
    """Quantized flash-decode tracks the fp oracle (§Perf A4 kernel)."""
    from repro.kernels.decode_attention import decode_attention_int8
    from repro.models.attention import dequantize_kv, quantize_kv
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    length = jnp.asarray([S // 2, S][:B], jnp.int32)
    k8, ksc = quantize_kv(k)
    v8, vsc = quantize_kv(v)
    got = decode_attention_int8(q, k8, ksc, v8, vsc, length, TROOP)
    # exact vs the oracle on the dequantized cache (isolates kernel error)
    want = R.decode_attention(q, dequantize_kv(k8, ksc, jnp.float32),
                              dequantize_kv(v8, vsc, jnp.float32), length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # within quantization noise of the unquantized oracle
    full = R.decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=0.1, atol=0.05)
