"""Paged KV-cache subsystem: page pool + block tables behind ``CacheBackend``.

The paper's decode workload streams the KV cache at OI~=1; every wasted byte
moves the roofline bound itself.  A dense per-slot cache of capacity S wastes
``(S - len) / S`` of its traffic-eligible bytes on padding.  This module
stores KV in fixed-size *pages* (a shared pool per layer) with per-slot
*block tables* mapping logical block -> physical page — the software analog
of TROOP mechanisms (D)/(E): pages are hardware-aligned layout granules
(``core.troop.sublane``), physically disjoint by construction, so the
decoupled streams of the paged decode kernel read conflict-free contiguous
regions regardless of how slots come and go.

Two backends implement one protocol:

  * ``DenseBackend``  — the original layout: per-slot dense caches,
    admission splices prefill rows with pad + dynamic_update_slice.
  * ``PagedBackend``  — page pool + host-side ``BlockAllocator``; admission
    scatters prefill KV into freshly allocated pages and frees them when the
    request finishes (no splicing, no padding traffic).

The engine (``serve.scheduler``) talks only to the protocol; the model
(``models.attention``) recognizes ``PagedKVCache`` leaves and routes decode
reads/writes through the block table it receives in the step batch.

Kept import-light on purpose: no top-level ``repro.models`` import (models
import this module for the ``PagedKVCache`` leaf type).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.troop import sublane

NULL_PAGE = 0          # page 0 is never allocated: idle slots point here


class PagedKVCache(NamedTuple):
    """Paged KV leaf: page pools, indexed by a per-slot block table.

    ``k_pool``/``v_pool``: (P, page, KV, hd) — or (L, P, page, KV, hd) when
    the layer group is stacked for ``lax.scan``.  The block table is *not*
    part of the leaf: it is per-step input (``batch["block_tables"]``), while
    the pools are per-step state — one table addresses every layer's pool.

    ``kv_dtype="int8"`` pools carry *scale pages* alongside: per-(token,
    head) absmax scales, (P, page, KV, 1), addressed by the SAME block
    table — the allocator/free list never knows they exist.
    """
    k_pool: jax.Array
    v_pool: jax.Array
    k_scale_pool: Optional[jax.Array] = None   # (.., P, page, KV, 1) if int8
    v_scale_pool: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale_pool is not None

    @property
    def page_size(self) -> int:
        return self.k_pool.shape[-3]

    @property
    def num_pages(self) -> int:
        return self.k_pool.shape[-4]


@dataclass(frozen=True)
class PageSpec:
    """Static paging geometry for one engine."""
    page_size: int            # tokens per page (a troop layout granule)
    num_pages: int            # physical pages per layer pool (incl. null)
    blocks_per_slot: int      # logical blocks per slot (= ceil(S / page))
    kv_dtype: str = "bfloat16"  # page-pool storage ("int8" adds scale pages)

    def validate(self):
        g = sublane(self.kv_dtype)
        assert self.page_size % g == 0, \
            f"page_size {self.page_size} not a multiple of the " \
            f"{g}-row layout granule for {self.kv_dtype} (mechanism D)"
        assert self.num_pages > NULL_PAGE + 1
        return self

    @staticmethod
    def for_engine(slots: int, cache_len: int, page_size: int,
                   num_pages: Optional[int] = None,
                   dtype="bfloat16") -> "PageSpec":
        blocks = -(-cache_len // page_size)
        pages = num_pages if num_pages is not None else slots * blocks + 1
        return PageSpec(page_size, pages, blocks,
                        jnp.dtype(dtype).name).validate()


class BlockAllocator:
    """Host-side free list over physical pages [1, num_pages)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: List[int]):
        for p in pages:
            assert p != NULL_PAGE
            self._free.append(p)


# --------------------------------------------------------------------------
# Tree splicing helpers (shared by both backends)
# --------------------------------------------------------------------------
def _batch_dim(dst_shape, src_shape, slots):
    """Batch dim for a B=1 splice: where dst == slots and src == 1 (prefer
    dim 1: stacked layer caches are (layers, B, ...))."""
    for d in (1, 0):
        if len(dst_shape) > d and dst_shape[d] == slots \
                and src_shape[d] == 1:
            return d
    raise ValueError(f"cannot locate batch dim: {dst_shape} vs {src_shape}")


def splice_row(dst, src, row: int, slot: int, slots: int,
               axis: Optional[int] = None):
    """Insert row ``row`` of a batched prefill array into slot ``slot`` of a
    batch-cache array, padding trailing (sequence) dims up to dst size.

    ``axis`` is the leaf's slot axis (from ``slot_axes`` — exact, no shape
    guessing); without it, fall back to the B=1 heuristic (compat shim).
    """
    bi = _batch_dim(dst.shape, src.shape, slots) if axis is None else axis
    if bi < 0:
        return dst                 # slot-independent leaf (shared pool)
    src = jax.lax.index_in_dim(src, row, axis=bi, keepdims=True)
    src = src.astype(dst.dtype)
    pads = []
    for d in range(src.ndim):
        tgt = 1 if d == bi else dst.shape[d]
        pads.append((0, tgt - src.shape[d]))
    src = jnp.pad(src, pads)
    start = [0] * dst.ndim
    start[bi] = slot
    return jax.lax.dynamic_update_slice(dst, src, tuple(start))


def slot_axes(model, slots: int, cache_len: int, page_spec=None):
    """Per-leaf slot axis of the cache tree, derived structurally: diff the
    ``eval_shape`` of ``init_caches`` at two slot counts — the axis whose
    extent changes is the slot axis (-1: slot-independent, e.g. a shared
    page pool).  No allocation, no shape heuristics — a state leaf whose
    head/seq extent happens to equal ``slots`` cannot be misidentified."""
    a = jax.eval_shape(
        lambda: model.init_caches(slots, cache_len, page_spec=page_spec))
    b = jax.eval_shape(
        lambda: model.init_caches(slots + 1, cache_len, page_spec=page_spec))

    def axis(x, y):
        for d, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return d
        return -1

    return jax.tree.map(axis, a, b)


def _is_paged(x) -> bool:
    return isinstance(x, PagedKVCache)


def _pool_scatter(pool, rows, pages: List[int]):
    """Write prefill KV rows into allocated pages of one pool leaf.

    pool: (P, page, KV, hd) or (L, P, page, KV, hd) when the layer group is
    stacked; rows: (T, KV, hd) / (L, T, KV, hd) correspondingly — padded or
    truncated to exactly fill the pages.
    """
    stacked = pool.ndim == 5
    t_axis = 1 if stacked else 0
    page = pool.shape[t_axis + 1]
    need = len(pages) * page
    T = rows.shape[t_axis]
    if T < need:
        pads = [(0, 0)] * rows.ndim
        pads[t_axis] = (0, need - T)
        rows = jnp.pad(rows, pads)
    elif T > need:
        rows = jax.lax.slice_in_dim(rows, 0, need, axis=t_axis)
    shp = (rows.shape[:t_axis] + (len(pages), page) + rows.shape[t_axis + 1:])
    buf = rows.reshape(shp).astype(pool.dtype)
    idx = jnp.asarray(pages, jnp.int32)
    if stacked:
        return pool.at[:, idx].set(buf)
    return pool.at[idx].set(buf)


# --------------------------------------------------------------------------
# Backends
# --------------------------------------------------------------------------
class CacheBackend(Protocol):
    """What the serving engine needs from a cache layout."""

    name: str

    def init_caches(self, model, slots: int, cache_len: int): ...

    def check_admissible(self, tokens: int):
        """Raise if a request needing ``tokens`` rows can NEVER be admitted
        (backpressure must not degenerate into a silent drop)."""
        ...

    def reserve(self, slot: int, tokens: int) -> bool:
        """Claim capacity for ``tokens`` total rows in ``slot``; False if
        the backing store is exhausted (engine defers admission)."""
        ...

    def admit(self, caches, prefill_caches, *, row: int, slot: int,
              prompt_len: int):
        """Move row ``row`` of a batched-prefill cache into ``slot``."""
        ...

    def release(self, slot: int):
        """Return ``slot``'s capacity to the pool (request finished)."""
        ...

    def batch_extras(self) -> Dict[str, Any]:
        """Extra decode-batch entries (e.g. the block table)."""
        ...

    def stats(self) -> Dict[str, Any]: ...


class DenseBackend:
    """The original layout: per-slot dense caches of capacity ``cache_len``."""

    name = "dense"

    def __init__(self):
        self.slots = 0

    def init_caches(self, model, slots: int, cache_len: int):
        self.slots = slots
        self.cache_len = cache_len
        self._axes = slot_axes(model, slots, cache_len)
        return model.init_caches(slots, cache_len)

    def check_admissible(self, tokens: int):
        pass

    def reserve(self, slot: int, tokens: int) -> bool:
        return True

    def admit(self, caches, prefill_caches, *, row: int, slot: int,
              prompt_len: int):
        return jax.tree.map(
            lambda dst, src, ax: splice_row(dst, src, row, slot, self.slots,
                                            axis=ax),
            caches, prefill_caches, self._axes)

    def release(self, slot: int):
        pass

    def batch_extras(self) -> Dict[str, Any]:
        return {}

    def stats(self) -> Dict[str, Any]:
        return {"backend": self.name, "cache_tokens": self.slots *
                getattr(self, "cache_len", 0)}


class PagedBackend:
    """Page pool + block tables; pages are troop layout granules.

    ``num_pages=None`` sizes the pool for full occupancy (capacity parity
    with dense); smaller values overcommit HBM — admission then *defers*
    when the pool is exhausted instead of OOMing, exactly like a production
    engine under memory pressure.

    ``kv_dtype="int8"`` stores pages quantized (per-(token, head) absmax
    scales in sibling scale pages — same block table, same allocator; the
    free list never changes).  Left ``None`` it follows the model's
    ``RuntimeConfig.kv_cache_dtype`` so a quantized engine is one flag;
    note the int8 layout granule is coarser (pages must be multiples of 32
    rows, not 16 — ``PageSpec.validate``).
    """

    name = "paged"

    def __init__(self, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None):
        self.page_size = page_size
        self.num_pages = num_pages
        self.kv_dtype = kv_dtype
        self.spec: Optional[PageSpec] = None

    def _resolve_kv_dtype(self, model) -> str:
        if self.kv_dtype is not None:
            return self.kv_dtype
        rt = getattr(model, "rt", None)
        if rt is not None and getattr(rt, "kv_cache_dtype", "") == "int8":
            return "int8"
        return jnp.dtype(model.cfg.dtype).name

    def init_caches(self, model, slots: int, cache_len: int):
        dtype = self._resolve_kv_dtype(model)
        self.slots = slots
        self.cache_len = cache_len
        self.spec = PageSpec.for_engine(slots, cache_len, self.page_size,
                                        self.num_pages, dtype)
        self.allocator = BlockAllocator(self.spec.num_pages)
        self.block_tables = np.full(
            (slots, self.spec.blocks_per_slot), NULL_PAGE, np.int32)
        self._slot_pages: Dict[int, List[int]] = {}
        self._axes = slot_axes(model, slots, cache_len, page_spec=self.spec)
        return model.init_caches(slots, cache_len, page_spec=self.spec)

    def _pages_needed(self, tokens: int) -> int:
        return -(-min(tokens, self.cache_len) // self.spec.page_size)

    def check_admissible(self, tokens: int):
        """Raised at submit time — before anything is popped or reserved —
        so an impossible request never strands queue entries or pages."""
        need = self._pages_needed(tokens)
        if need > self.spec.num_pages - 1:
            raise ValueError(
                f"request needs {need} pages but the pool holds "
                f"{self.spec.num_pages - 1}: it can never be admitted — "
                f"raise num_pages or lower prompt_len + max_new_tokens")

    def reserve(self, slot: int, tokens: int) -> bool:
        pages = self.allocator.alloc(self._pages_needed(tokens))
        if pages is None:
            return False
        self._slot_pages[slot] = pages
        self.block_tables[slot] = NULL_PAGE
        self.block_tables[slot, :len(pages)] = pages
        return True

    def admit(self, caches, prefill_caches, *, row: int, slot: int,
              prompt_len: int):
        pages = self._slot_pages[slot]
        page = self.spec.page_size
        n_prefill = -(-prompt_len // page)

        def one(dst, src):
            if _is_paged(dst):
                # src is the dense prefill KVCache for this sublayer;
                # its batch axis is 0 (unstacked) or 1 (stacked layers)
                b_axis = 0 if dst.k_pool.ndim == 4 else 1

                def rows(a):
                    return jax.lax.index_in_dim(a, row, axis=b_axis,
                                                keepdims=False)

                use = pages[:n_prefill]
                if not dst.quantized:
                    return PagedKVCache(
                        _pool_scatter(dst.k_pool, rows(src.k), use),
                        _pool_scatter(dst.v_pool, rows(src.v), use))
                # int8 pools: scatter quantized rows + their scale rows.
                # An int8 *prefill* cache (rt.kv_cache_dtype == "int8")
                # already carries per-token scales — reuse them verbatim so
                # paged and dense int8 engines are numerically identical;
                # a bf16 prefill cache is quantized here, at admit.
                if getattr(src, "quantized", False):
                    k8, ks = rows(src.k), rows(src.k_scale)
                    v8, vs = rows(src.v), rows(src.v_scale)
                else:
                    from repro.quant.tensor import quantize_kv
                    k8, ks = quantize_kv(rows(src.k))
                    v8, vs = quantize_kv(rows(src.v))
                return PagedKVCache(
                    _pool_scatter(dst.k_pool, k8, use),
                    _pool_scatter(dst.v_pool, v8, use),
                    _pool_scatter(dst.k_scale_pool, ks, use),
                    _pool_scatter(dst.v_scale_pool, vs, use))
            return dst

        # paged leaves first (is_leaf stops recursion there), then the
        # remaining dense leaves (mamba/rwkv state, MLA, cross-attn KV,
        # int8 scales) take the dense splice path along their slot axis.
        caches = jax.tree.map(one, caches, prefill_caches, is_leaf=_is_paged)

        def dense(dst, src, ax):
            if _is_paged(dst):
                return dst
            return splice_row(dst, src, row, slot, self.slots, axis=ax)

        return jax.tree.map(dense, caches, prefill_caches, self._axes,
                            is_leaf=_is_paged)

    def release(self, slot: int):
        pages = self._slot_pages.pop(slot, None)
        if pages:
            self.allocator.free(pages)
        self.block_tables[slot] = NULL_PAGE

    def batch_extras(self) -> Dict[str, Any]:
        return {"block_tables": jnp.asarray(self.block_tables)}

    def stats(self) -> Dict[str, Any]:
        sp = self.spec
        return {
            "backend": self.name,
            "page_size": sp.page_size if sp else self.page_size,
            "num_pages": sp.num_pages if sp else self.num_pages,
            "kv_dtype": sp.kv_dtype if sp else self.kv_dtype,
            "pages_free": self.allocator.num_free if sp else None,
            "pages_in_use": (sp.num_pages - 1 - self.allocator.num_free)
            if sp else None,
        }


def make_backend(backend) -> CacheBackend:
    """'dense' | 'paged' | an instance -> a CacheBackend instance."""
    if backend is None:
        return DenseBackend()
    if isinstance(backend, str):
        if backend == "dense":
            return DenseBackend()
        if backend == "paged":
            return PagedBackend()
        raise ValueError(f"unknown cache backend {backend!r}")
    return backend


def bucket_length(n: int, min_bucket: int = 8,
                  cap: Optional[int] = None) -> int:
    """Power-of-2 prefill bucket for a prompt of length ``n`` — one XLA
    prefill compile per bucket, ever (the recompile-free admission path)."""
    b = max(min_bucket, 1 << max(0, math.ceil(math.log2(max(n, 1)))))
    if cap is not None:
        b = min(b, cap)
    return b
