"""Decoder-LM assembly: layer groups + scan-over-layers + caches.

Layers are grouped into maximal runs that tile a fixed (mixer, ffn) pattern;
parameters of a group are stacked over its repeats and the group is executed
with ``lax.scan`` (small HLO, fast multi-pod compiles).  Heterogeneous stacks
(Jamba's 8-layer period, DeepSeek's leading dense layer) become multiple
groups / multi-sublayer patterns.

Three entry points: ``train_logits`` / ``prefill`` / ``decode_step``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import partitioning as PT
from repro.models import attention as A
from repro.models import mamba as MB
from repro.models import mla as ML
from repro.models import moe as MOE
from repro.models import modules as M
from repro.models import rwkv as RW


@dataclass(frozen=True)
class RuntimeConfig:
    """Static runtime switches (jit static arg)."""
    remat: str = "dots"            # none | dots | full
    moe_groups: int = 1            # routing groups (align with data shards)
    mla_decode: str = "absorb"     # absorb | expand
    cache_dtype: str = "bfloat16"  # bf16 | int8 (quantized KV, §Perf)
    scan_layers: bool = True
    loss_chunk: int = 0            # 0 = unchunked softmax xent
    paged_kernel_decode: bool = False  # paged decode via the tuned Pallas
    #   kernel (no gathered dense view); default off: the jnp path is the
    #   GSPMD-shardable reference (interpret-mode Pallas is slow on CPU)
    # ---- repro.quant (DESIGN.md §5): a quantized engine is one flag ----
    quantize_weights: str = "none"  # none|int8|int4|mx4|fp8: matmul-weight
    #   quantization policy tag; the launcher applies
    #   repro.quant.quantize_params and apply_dense dequantizes on the fly
    kv_cache_dtype: str = ""       # "" -> cache_dtype. "int8" under the
    #   paged backend stores int8 page pools + scale pages (dense backends
    #   fall back to the per-slot int8 layout, same as cache_dtype="int8")

    def kv_dtype(self) -> str:
        """Resolved KV-cache storage dtype (serving alias wins)."""
        return self.kv_cache_dtype or self.cache_dtype


@dataclass(frozen=True)
class LayerGroup:
    pattern: Tuple[Tuple[str, str], ...]   # ((mixer, ffn), ...) per repeat
    repeats: int


def plan_groups(cfg) -> List[LayerGroup]:
    kinds = cfg.layer_kinds()
    f = cfg.first_dense_layers
    groups = [LayerGroup((kinds[i],), 1) for i in range(f)]
    rest = kinds[f:]
    if not rest:
        return groups
    import math
    P = abs(len(cfg.pattern()) * cfg.moe_period) // math.gcd(
        len(cfg.pattern()), cfg.moe_period) if cfg.moe_period else len(cfg.pattern())
    P = max(P, 1)
    if len(rest) % P:
        P = len(rest)               # fallback: one big unrolled group
    pat = tuple(rest[:P])
    for a in range(len(rest) // P):
        assert tuple(rest[a * P:(a + 1) * P]) == pat, "non-periodic layer kinds"
    groups.append(LayerGroup(pat, len(rest) // P))
    return groups


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def _init_sublayer(key, cfg, mixer: str, ffn: str):
    ks = jax.random.split(key, 4)
    p = {"norm1": M.norm_init(cfg.norm, cfg.d_model)}
    if mixer == "attn":
        p["mixer"] = (ML.mla_init(ks[0], cfg) if cfg.attention == "mla"
                      else A.attention_init(ks[0], cfg))
        if cfg.encoder_decoder:
            p["xattn"] = A.attention_init(ks[3], cfg, cross=True)
            p["norm_x"] = M.norm_init(cfg.norm, cfg.d_model)
    elif mixer == "mamba":
        p["mixer"] = MB.mamba_init(ks[0], cfg)
    elif mixer == "rwkv":
        p["mixer"] = RW.rwkv_time_mix_init(ks[0], cfg)
    p["norm2"] = M.norm_init(cfg.norm, cfg.d_model)
    if ffn == "mlp":
        p["ffn"] = M.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    elif ffn == "moe":
        p["ffn"] = MOE.moe_init(ks[1], cfg)
    elif ffn == "rwkv_cm":
        p["ffn"] = RW.rwkv_channel_mix_init(ks[1], cfg)
    return p


def _init_repeat(key, cfg, pattern):
    ks = jax.random.split(key, len(pattern))
    return [_init_sublayer(k, cfg, m, f) for k, (m, f) in zip(ks, pattern)]


def _stack_layer_axis(tree):
    return jax.tree.map(lambda p: M.Param(p.value, ("layers",) + p.axes),
                        tree, is_leaf=M.is_param)


def init_group(key, cfg, g: LayerGroup):
    if g.repeats == 1:
        return _init_repeat(key, cfg, g.pattern)
    ks = jax.random.split(key, g.repeats)
    stacked = jax.vmap(lambda k: _init_repeat(k, cfg, g.pattern))(ks)
    return _stack_layer_axis(stacked)


def init_lm(key, cfg):
    groups = plan_groups(cfg)
    ks = jax.random.split(key, len(groups) + 4)
    p = {
        "embed": M.embed_init(ks[0], cfg.vocab_size, cfg.d_model),
        "groups": [init_group(ks[2 + i], cfg, g) for i, g in enumerate(groups)],
        "final_norm": M.norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = M.dense_init(ks[1], cfg.d_model, cfg.vocab_size,
                                    ("embed", "vocab"))
    if cfg.pos_emb == "learned":
        p["pos_table"] = M.Param(
            0.01 * jax.random.normal(
                ks[-1], (cfg.max_position_embeddings, cfg.d_model),
                jnp.float32), (None, "embed"))
    return p


# --------------------------------------------------------------------------
# Sublayer application
# --------------------------------------------------------------------------
def _zero_state(cfg, mixer, B, dtype):
    if mixer == "mamba":
        return {"mixer": MB.init_mamba_state(cfg, B, jnp.float32)}
    if mixer == "rwkv":
        return {"mixer": RW.init_rwkv_state(cfg, B, dtype)}
    return {}


def _apply_sublayer(p, cfg, rt, x, *, mixer, ffn, positions, state, dtype,
                    decode=False, pos=None, return_cache=False, enc_kv=None,
                    pages=None, chunk=None):
    """Returns (x, new_state_or_cache, aux).

    ``chunk`` ({offset, valid, stage_base} arrays) selects chunked-prefill
    mode: a slab of tokens is written through the paged cache's block table
    and attends with a query offset — attention-only archs (a recurrent
    mixer scans through state and cannot resume mid-prompt from pages).
    """
    aux = jnp.zeros((), jnp.float32)
    out_state = {}
    x = PT.constrain(x, ("batch", None, None))
    h = M.apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    if chunk is not None:
        if mixer != "attn" or cfg.attention == "mla" or "xattn" in p:
            raise ValueError(
                "chunked prefill supports causal-attention archs only "
                f"(got mixer={mixer!r}, attention={cfg.attention!r})")
        # verify slabs (speculative decoding) bypass the bf16 chunk stage:
        # they quantize-then-gather through the pools like plain decode,
        # which is exactly what keeps verify bit-identical to decode
        stage = None if chunk.get("no_stage") else state.get("stage")
        o, c, stg = A.apply_attention_chunk_paged(
            p["mixer"], cfg, h, state["mixer"], chunk["offset"],
            chunk["valid"], chunk["stage_base"], dtype, block_tables=pages,
            stage=stage,
            use_kernel=rt.paged_kernel_decode or M.kernel_routed())
        out_state["mixer"] = c
        if stg is not None:
            out_state["stage"] = stg
        elif "stage" in state:
            # keep the cache tree structure stable (jit donation) when the
            # stage buffer exists but this pass bypassed it
            out_state["stage"] = state["stage"]
        x = x + o
        h = M.apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
        if ffn == "mlp":
            o = M.apply_mlp(p["ffn"], h, cfg.act, dtype)
        elif ffn == "moe":
            o, aux = MOE.apply_moe(p["ffn"], cfg, h, dtype=dtype,
                                   num_groups=rt.moe_groups)
        else:
            raise ValueError(f"chunked prefill: unsupported ffn {ffn!r}")
        return x + o, out_state, aux
    if mixer == "attn":
        if decode:
            if cfg.attention == "mla":
                o, c = ML.apply_mla_decode(p["mixer"], cfg, h, state["mixer"],
                                           pos, dtype, rt.mla_decode)
            else:
                o, c = A.apply_attention_decode(
                    p["mixer"], cfg, h, state["mixer"], pos, dtype,
                    block_tables=pages,
                    use_kernel=rt.paged_kernel_decode or
                    M.kernel_routed())
            out_state["mixer"] = c
        else:
            if cfg.attention == "mla":
                o = ML.apply_mla(p["mixer"], cfg, h, positions=positions,
                                 dtype=dtype)
                if return_cache:
                    c_kv, k_pe = ML._latent(p["mixer"], cfg, h, positions,
                                            dtype)
                    out_state["mixer"] = ML.MLACache(c_kv, k_pe)
            else:
                causal = enc_kv != "encoder"    # encoder stack: bidirectional
                o = A.apply_attention(p["mixer"], cfg, h, positions=positions,
                                      dtype=dtype, causal=causal,
                                      return_kv=return_cache)
                if return_cache:
                    o, kv = o
                    if rt.kv_dtype() == "int8":     # §Perf A4
                        qk, ks = A.quantize_kv(kv.k)
                        qv, vs = A.quantize_kv(kv.v)
                        kv = A.KVCache(qk, qv, ks, vs)
                    out_state["mixer"] = kv
    elif mixer == "mamba":
        o, st = MB.apply_mamba(p["mixer"], cfg, h, state["mixer"], dtype)
        out_state["mixer"] = st
    elif mixer == "rwkv":
        o, st = RW.apply_time_mix(p["mixer"], cfg, h, state["mixer"], dtype)
        out_state["mixer"] = st
    else:
        raise KeyError(mixer)
    x = x + o

    # cross-attention (whisper decoder). ``enc_kv`` is the encoder output
    # during prefill (per-layer K/V computed + cached here); during decode the
    # per-layer K/V ride along in the cache ("xkv").
    if "xattn" in p and (decode or (enc_kv is not None
                                    and not isinstance(enc_kv, str))):
        if decode:
            xkv = state["xkv"]
        else:
            xkv = A.cross_kv(p["xattn"], cfg, enc_kv.astype(dtype), dtype)
        h = M.apply_norm(p["norm_x"], x, cfg.norm, cfg.norm_eps)
        x = x + A.apply_cross_attention(p["xattn"], cfg, h, xkv, dtype)
        out_state["xkv"] = xkv

    h = M.apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
    if ffn == "mlp":
        o = M.apply_mlp(p["ffn"], h, cfg.act, dtype)
    elif ffn == "moe":
        o, aux = MOE.apply_moe(p["ffn"], cfg, h, dtype=dtype,
                               num_groups=rt.moe_groups)
    elif ffn == "rwkv_cm":
        st = out_state.get("mixer", state.get("mixer"))
        o, st = RW.apply_channel_mix(p["ffn"], cfg, h, st, dtype)
        out_state["mixer"] = st
    x = x + o
    # the chunk-stage buffer (chunked prefill over int8 pools) rides the
    # cache tree through decode steps untouched
    if "stage" in state and "stage" not in out_state:
        out_state["stage"] = state["stage"]
    return x, out_state, aux


def _apply_repeat(ps, cfg, rt, x, *, pattern, positions, states, dtype,
                  decode=False, pos=None, return_cache=False, enc_kv=None,
                  pages=None, chunk=None):
    new_states, aux = [], jnp.zeros((), jnp.float32)
    for p, (mixer, ffn), st in zip(ps, pattern, states):
        x, ns, a = _apply_sublayer(
            p, cfg, rt, x, mixer=mixer, ffn=ffn, positions=positions,
            state=st, dtype=dtype, decode=decode, pos=pos,
            return_cache=return_cache, enc_kv=enc_kv, pages=pages,
            chunk=chunk)
        new_states.append(ns)
        aux = aux + a
    return x, new_states, aux


def _run_groups(params_groups, groups, cfg, rt, x, *, positions, states,
                dtype, decode=False, pos=None, return_cache=False,
                enc_kv=None, pages=None, chunk=None):
    """states: list (per group) of stacked per-repeat state lists."""
    out_states = []
    aux_total = jnp.zeros((), jnp.float32)

    for gi, g in enumerate(groups):
        ps, sts = params_groups[gi], states[gi]

        def body(x, p_rep, st_rep):
            return _apply_repeat(p_rep, cfg, rt, x, pattern=g.pattern,
                                 positions=positions, states=st_rep,
                                 dtype=dtype, decode=decode, pos=pos,
                                 return_cache=return_cache, enc_kv=enc_kv,
                                 pages=pages, chunk=chunk)

        if rt.remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots)
        elif rt.remat == "dots_tp":
            # B4: also save post-all-reduce activations ("tp_out") so the
            # backward pass never re-runs TP collectives.
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.save_from_both_policies(
                    jax.checkpoint_policies.checkpoint_dots,
                    jax.checkpoint_policies.save_only_these_names("tp_out")))
        elif rt.remat == "full":
            body = jax.checkpoint(body)

        if g.repeats == 1 or not rt.scan_layers:
            if g.repeats == 1:
                x, ns, a = body(x, ps, sts)
                out_states.append(ns)
                aux_total = aux_total + a
            else:
                ns_list = []
                for r in range(g.repeats):
                    p_r = jax.tree.map(lambda v: v[r], ps)
                    s_r = jax.tree.map(lambda v: v[r], sts)
                    x, ns, a = body(x, p_r, s_r)
                    ns_list.append(ns)
                    aux_total = aux_total + a
                out_states.append(jax.tree.map(
                    lambda *vs: jnp.stack(vs), *ns_list))
        else:
            def scan_f(carry, xs):
                x, aux = carry
                p_rep, st_rep = xs
                x, ns, a = body(x, p_rep, st_rep)
                return (x, aux + a), ns

            (x, aux_total), ns = jax.lax.scan(
                scan_f, (x, aux_total), (ps, sts))
            out_states.append(ns)
    return x, out_states, aux_total


# --------------------------------------------------------------------------
# Input embedding (+ modality frontend stubs)
# --------------------------------------------------------------------------
def embed_inputs(p, cfg, batch, dtype, offset=0):
    x = M.apply_embed(p["embed"], batch["tokens"], dtype)
    if cfg.frontend == "vision" and "frontend" in batch:
        x = jnp.concatenate([batch["frontend"].astype(dtype), x], axis=1)
    if cfg.pos_emb == "learned":
        T = x.shape[1]
        pos_tab = jax.lax.dynamic_slice_in_dim(
            p["pos_table"], offset, T, axis=0) if isinstance(offset, int) \
            else jnp.take(p["pos_table"], offset[:, None] + jnp.arange(T), axis=0)
        x = x + pos_tab.astype(dtype)
    elif cfg.pos_emb == "sinusoidal":
        x = x + M.sinusoidal_pos(x.shape[1], cfg.d_model).astype(dtype)
    return x


def readout(p, cfg, x, dtype):
    x = M.apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = M.apply_unembed(p["embed"], x, dtype)
    else:
        logits = M.apply_dense(p["lm_head"], x, dtype)
    return PT.constrain(logits, ("batch", None, "vocab"))


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------
def _zero_states(cfg, groups, B, dtype, stacked=True):
    out = []
    for g in groups:
        per_rep = [_zero_state(cfg, m, B, dtype) for (m, f) in g.pattern]
        if g.repeats > 1 and stacked:
            per_rep = jax.tree.map(
                lambda v: jnp.broadcast_to(v, (g.repeats,) + v.shape),
                per_rep)
        out.append(per_rep)
    return out


def train_logits(params, cfg, rt, batch):
    """batch: tokens (B,T) [+ frontend embeds]. Returns (logits, aux)."""
    dtype = jnp.dtype(cfg.dtype)
    groups = plan_groups(cfg)
    x = embed_inputs(params, cfg, batch, dtype)
    B, T = x.shape[:2]
    positions = jnp.arange(T)[None, :]
    states = _zero_states(cfg, groups, B, dtype)
    x, _, aux = _run_groups(params["groups"], groups, cfg, rt, x,
                            positions=positions, states=states, dtype=dtype)
    return readout(params, cfg, x, dtype), aux


def prefill(params, cfg, rt, batch):
    """Full-sequence forward that also returns decode caches."""
    dtype = jnp.dtype(cfg.dtype)
    cache_dtype = jnp.dtype(rt.kv_dtype()) if rt.kv_dtype() != "int8" \
        else dtype
    groups = plan_groups(cfg)
    x = embed_inputs(params, cfg, batch, dtype)
    B, T = x.shape[:2]
    positions = jnp.arange(T)[None, :]
    states = _zero_states(cfg, groups, B, dtype)
    x, caches, aux = _run_groups(params["groups"], groups, cfg, rt, x,
                                 positions=positions, states=states,
                                 dtype=dtype, return_cache=True)
    return readout(params, cfg, x, dtype), caches


def init_caches(cfg, rt, B, S, dtype, page_spec=None, chunk_stage: int = 0):
    """Pre-allocated decode caches for every group/sublayer.

    With ``page_spec`` (a ``serve.kvcache.PageSpec``) plain attention KV
    leaves become shared ``PagedKVCache`` page pools addressed by the
    engine's block table — int8 pools with scale pages when the spec says
    ``kv_dtype="int8"`` (DESIGN.md §5); MLA, dense-int8
    (``cache_dtype="int8"`` without an int8 page spec) and cross-attention
    caches keep the dense per-slot layout (documented fallback, §4).

    ``chunk_stage`` (a chunk size, > 0 under the chunked-prefill engine)
    adds a one-slot bf16 ``ChunkStage`` buffer next to *quantized* paged
    leaves so chunked prefill never re-reads its own rows through int8
    pages (DESIGN.md §6); bf16 pools need no stage.
    """
    groups = plan_groups(cfg)
    paged_int8 = page_spec is not None and \
        jnp.dtype(page_spec.kv_dtype) == jnp.dtype(jnp.int8)
    out = []
    for g in groups:
        per_rep = []
        for (m, f) in g.pattern:
            if m == "attn":
                quant = rt.kv_dtype() == "int8" and cfg.attention != "mla"
                if cfg.attention == "mla":
                    c = ML.init_mla_cache(cfg, B, S, dtype)
                elif page_spec is not None and (not quant or paged_int8):
                    c = A.init_paged_cache(cfg, page_spec, dtype)
                else:
                    c = A.init_cache(cfg, B, S, dtype, quantized=quant)
                entry = {"mixer": c}
                if chunk_stage > 0 and paged_int8 and cfg.attention != "mla":
                    # cover the gathered view plus a full pad chunk so the
                    # staging write never clamps at the sequence end
                    ps = page_spec.page_size
                    S_stage = max(-(-S // ps) * ps, S + chunk_stage)
                    KV, hd = cfg.num_kv_heads, cfg.head_dim
                    entry["stage"] = A.ChunkStage(
                        jnp.zeros((1, S_stage, KV, hd), jnp.bfloat16),
                        jnp.zeros((1, S_stage, KV, hd), jnp.bfloat16))
                if cfg.encoder_decoder:
                    entry["xkv"] = A.init_cache(
                        cfg, B, cfg.cross_attention_len, dtype)
                per_rep.append(entry)
            else:
                per_rep.append(_zero_state(cfg, m, B, dtype))
        if g.repeats > 1:
            per_rep = jax.tree.map(
                lambda v: jnp.broadcast_to(
                    v, (g.repeats,) + v.shape).astype(v.dtype), per_rep)
        out.append(per_rep)
    return out


def chunk_prefill_step(params, cfg, rt, batch, caches):
    """One chunked-prefill slab against the shared paged caches.

    batch: tokens (B, C) right-padded; offset (B,) absolute position of
    token 0; valid (B,) real rows; stage_base (B,) first position owned by
    this request (== the shared-prefix length); block_tables (B, nblk).
    Returns (last-valid-row logits (B, V), new caches) — the logits row
    only matters on a prompt's final chunk, where its argmax is the
    request's first generated token (same greedy readout as the bucketed
    ``prefill_step``).
    """
    dtype = jnp.dtype(cfg.dtype)
    groups = plan_groups(cfg)
    offset, valid = batch["offset"], batch["valid"]
    x = embed_inputs(params, cfg, batch, dtype, offset=offset)
    C = x.shape[1]
    positions = offset[:, None] + jnp.arange(C)[None, :]
    chunk = {"offset": offset, "valid": valid,
             "stage_base": batch.get("stage_base", jnp.zeros_like(offset))}
    x, new_caches, _ = _run_groups(
        params["groups"], groups, cfg, rt, x, positions=positions,
        states=caches, dtype=dtype, chunk=chunk,
        pages=batch.get("block_tables"))
    # gather each row's last valid position BEFORE the O(V) readout (the
    # same trick as the bucketed prefill: never unembed discarded rows)
    last = jnp.take_along_axis(x, (valid - 1)[:, None, None], axis=1)
    logits = readout(params, cfg, last, dtype)          # (B, 1, V)
    return logits[:, 0], new_caches


def verify_step(params, cfg, rt, batch, caches):
    """Score a speculative window: a chunked slab keeping ALL row logits.

    batch: tokens (B, W) = [last emitted token, d_1..d_k] right-padded;
    offset (B,) the last emitted token's position; valid (B,) = k_eff + 1
    real rows (0 disables a row); block_tables (B, nblk).  Returns
    (logits (B, W, V), new caches): row i conditions on everything up to
    and including the first i draft tokens, i.e. row i scores position
    offset + i + 1.  KV rows offset..offset+valid-1 are written through
    the block table exactly like chunked prefill — int8 pools get the
    same quantize-then-gather treatment as decode, so verify logits match
    decode logits bit-for-bit — but the bf16 chunk stage is bypassed
    (``no_stage``): rejected rows are rewritten by the next verify pass
    (whose offset lands exactly on the first rejected row) before anyone
    can attend over them.
    """
    dtype = jnp.dtype(cfg.dtype)
    groups = plan_groups(cfg)
    offset, valid = batch["offset"], batch["valid"]
    x = embed_inputs(params, cfg, batch, dtype, offset=offset)
    C = x.shape[1]
    positions = offset[:, None] + jnp.arange(C)[None, :]
    chunk = {"offset": offset, "valid": valid,
             "stage_base": jnp.zeros_like(offset), "no_stage": True}
    x, new_caches, _ = _run_groups(
        params["groups"], groups, cfg, rt, x, positions=positions,
        states=caches, dtype=dtype, chunk=chunk,
        pages=batch.get("block_tables"))
    return readout(params, cfg, x, dtype), new_caches


def decode_step(params, cfg, rt, batch, caches):
    """batch: tokens (B,1), pos (B,) [+ block_tables (B,nblk) when the cache
    is paged]. Returns (logits (B,1,V), new caches)."""
    dtype = jnp.dtype(cfg.dtype)
    groups = plan_groups(cfg)
    pos = batch["pos"]
    x = embed_inputs(params, cfg, batch, dtype, offset=pos)
    x, new_caches, _ = _run_groups(
        params["groups"], groups, cfg, rt, x, positions=pos[:, None],
        states=caches, dtype=dtype, decode=True, pos=pos,
        enc_kv=batch.get("enc_kv"), pages=batch.get("block_tables"))
    return readout(params, cfg, x, dtype), new_caches
