"""repro.quant — quantization as a first-class subsystem.

Quantization is the software lever that moves the memory roofline itself:
at OI ~= 1 every operand byte is the bound, so int8 halves (int4 quarters)
the attainable decode time (DESIGN.md §5).  Three layers:

  * ``tensor``  — ``QuantizedTensor`` pytree, absmax calibration,
    grouped/per-tensor quantize/dequantize, int4 nibble packing; plus the
    repo's two historical int8 layouts (``quantize_kv``, ``quantize_int8``)
    as thin views.
  * ``params``  — ``quantize_params``: policy-driven pass over a model's
    params pytree (MLP/attention projections yes; embeddings/norms no).
  * ``kernels`` — fused-dequant Pallas kernels (``qgemv``,
    ``batched_qgemv``, and the MX family ``mx_qgemv`` /
    ``batched_mx_qgemv`` / ``mx_qgemv_swiglu`` / ``grouped_expert_qgemv``),
    registered with ``repro.tune`` under bytes models
    that count quantized widths and scale traffic.  Imported lazily so
    model code can use the tensor layer without touching Pallas; the int8
    decode-attention kernels live with their bf16 siblings in
    ``repro.kernels.decode_attention``.
"""
from repro.quant.params import (default_policy, quantize_params,
                                quantized_stats)
from repro.quant.tensor import (QuantizedTensor, absmax_scales, dequantize,
                                dequantize_int8, dequantize_kv,
                                dequantize_values, e8m0_decode, fp4_decode,
                                fp4_encode, granule, pack_fp4, pack_int4,
                                quantize, quantize_int8, quantize_kv,
                                quantize_mx, unpack_fp4, unpack_int4)

_LAZY_KERNELS = ("qgemv", "batched_qgemv", "mx_qgemv", "batched_mx_qgemv",
                 "mx_qgemv_swiglu", "grouped_expert_qgemv")

__all__ = [
    "QuantizedTensor", "absmax_scales", "quantize", "dequantize",
    "dequantize_values", "pack_int4", "unpack_int4", "granule",
    "quantize_mx", "fp4_encode", "fp4_decode", "pack_fp4", "unpack_fp4",
    "e8m0_decode",
    "quantize_kv", "dequantize_kv", "quantize_int8", "dequantize_int8",
    "quantize_params", "default_policy", "quantized_stats",
    *_LAZY_KERNELS,
]


def __getattr__(name):
    # Pallas kernels resolve lazily: keeps `import repro.quant` light for
    # model code while `repro.quant.qgemv` still works.
    if name in _LAZY_KERNELS:
        from repro.quant import kernels as _k
        return getattr(_k, name)
    raise AttributeError(f"module 'repro.quant' has no attribute {name!r}")
