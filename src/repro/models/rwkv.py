"""RWKV-6 "Finch" block: time-mix (WKV6, data-dependent decay) + channel-mix.

The reference WKV6 recurrence is a ``lax.scan`` over time (numerically exact,
the oracle for the Pallas ``rwkv6`` kernel, which evaluates the same
recurrence with the state resident in VMEM).

State per layer (decode): token-shift vectors for time/channel mix
(B, d) each + WKV state (B, H, hd, hd)  — O(1) in sequence length, which is
why this arch runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partitioning as PT
from repro.models import modules as M


class RWKVState(NamedTuple):
    shift_tm: jax.Array    # (B, d)   last token seen by time-mix
    shift_cm: jax.Array    # (B, d)   last token seen by channel-mix
    wkv: jax.Array         # (B, H, hd, hd) fp32 recurrence state


def rwkv_time_mix_init(key, cfg):
    d, r = cfg.d_model, cfg.rwkv
    H, hd = cfg.num_heads, r.head_dim
    ks = jax.random.split(key, 12)
    z = lambda *s: jnp.zeros(s, jnp.float32)
    p = {
        "maa_x": M.Param(z(d), ("embed",)),
        "maa_wkvrg": M.Param(z(5, d), (None, "embed")),
        "maa_w1": M.dense_init(ks[0], d, 5 * r.mix_lora, ("embed", None),
                               scale=0.01),
        "maa_w2": M.Param(0.01 * jax.random.normal(
            ks[1], (5, r.mix_lora, d), jnp.float32), (None, None, "embed")),
        "decay": M.Param(z(H, hd) - 5.0, (None, None)),
        "decay_w1": M.dense_init(ks[2], d, r.decay_lora, ("embed", None),
                                 scale=0.01),
        "decay_w2": M.dense_init(ks[3], r.decay_lora, d, (None, "embed"),
                                 scale=0.01),
        "bonus_u": M.Param(0.5 * jnp.ones((H, hd), jnp.float32), (None, None)),
        "wr": M.dense_init(ks[4], d, d, ("embed", "qkv_out")),
        "wk": M.dense_init(ks[5], d, d, ("embed", "qkv_out")),
        "wv": M.dense_init(ks[6], d, d, ("embed", "qkv_out")),
        "wg": M.dense_init(ks[7], d, d, ("embed", "qkv_out")),
        "wo": M.dense_init(ks[8], d, d, ("qkv_out", "embed")),
        "ln_x": M.norm_init("layernorm", d, ("embed",)),
    }
    return p


def rwkv_channel_mix_init(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    z = lambda *s: jnp.zeros(s, jnp.float32)
    return {
        "maa_k": M.Param(z(d), ("embed",)),
        "maa_r": M.Param(z(d), ("embed",)),
        "wk": M.dense_init(ks[0], d, ff, ("embed", "ffn")),
        "wv": M.dense_init(ks[1], ff, d, ("ffn", "embed")),
        "wr": M.dense_init(ks[2], d, d, ("embed", "qkv_out")),
    }


def wkv6_scan(r, k, v, w, u, state0):
    """Reference WKV6 recurrence (fp32 scan over time).

    r,k,v,w: (B, T, H, hd); u: (H, hd); state0: (B, H, hd, hd).
    y_t = r_t @ S_{t-1} + (r_t . (u*k_t)) v_t ;  S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    """
    r, k, v, w = (a.astype(jnp.float32) for a in (r, k, v, w))

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw                                 # (B,H,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, S)
        y = y + jnp.sum(rt * u[None] * kt, -1, keepdims=True) * vt
        S = wt[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), state                      # (B,T,H,hd)


def _token_shift(x, prev):
    """[prev, x_0, ..., x_{T-2}] along time."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def apply_time_mix(p, cfg, x, state: RWKVState, dtype):
    B, T, d = x.shape
    r_cfg = cfg.rwkv
    H, hd = cfg.num_heads, r_cfg.head_dim
    xf = x.astype(jnp.float32)
    sx = _token_shift(xf, state.shift_tm.astype(jnp.float32)) - xf

    xxx = xf + sx * p["maa_x"]
    lora = jnp.tanh(xxx @ p["maa_w1"]["w"]).reshape(B, T, 5, r_cfg.mix_lora)
    mix = jnp.einsum("btfm,fmd->fbtd", lora, p["maa_w2"])     # (5,B,T,d)
    xw, xk, xv, xr, xg = (
        xf + sx * (p["maa_wkvrg"][i] + mix[i]) for i in range(5))

    # §Perf D1 (refuted, kept for the record): replicating the WKV head dim
    # removes GSPMD's uneven-padding permutes but the full-tensor gathers
    # cost MORE (t_coll 13.6 -> 16.1 s measured); uneven 40/16 head
    # sharding is the better trade on this mesh.
    hax = ("batch", None, "heads", None)
    r = PT.constrain(M.apply_dense(p["wr"], xr.astype(dtype), dtype)
                     .reshape(B, T, H, hd), hax, allow_uneven=True)
    k = PT.constrain(M.apply_dense(p["wk"], xk.astype(dtype), dtype)
                     .reshape(B, T, H, hd), hax, allow_uneven=True)
    v = PT.constrain(M.apply_dense(p["wv"], xv.astype(dtype), dtype)
                     .reshape(B, T, H, hd), hax, allow_uneven=True)
    g = jax.nn.silu(M.apply_dense(p["wg"], xg.astype(dtype), dtype))

    dec = p["decay"][None, None] + (
        jnp.tanh(xw @ p["decay_w1"]["w"]) @ p["decay_w2"]["w"]
    ).reshape(B, T, H, hd)
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32)))            # (0,1) decay
    w = PT.constrain(w, hax, allow_uneven=True)

    y, wkv = wkv6_scan(r, k, v, w, p["bonus_u"].astype(jnp.float32),
                       state.wkv)
    # GroupNorm(H groups) over the head dim, as in RWKV-6.
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, T, d)
    y = (y * p["ln_x"]["scale"] + p["ln_x"]["bias"]).astype(dtype)
    out = M.apply_dense(p["wo"], (y * g).astype(dtype), dtype)
    new_state = RWKVState(x[:, -1, :], state.shift_cm, wkv)
    return out, new_state


def apply_channel_mix(p, cfg, x, state: RWKVState, dtype):
    xf = x.astype(jnp.float32)
    sx = _token_shift(xf, state.shift_cm.astype(jnp.float32)) - xf
    xk = (xf + sx * p["maa_k"]).astype(dtype)
    xr = (xf + sx * p["maa_r"]).astype(dtype)
    k = jnp.square(jax.nn.relu(M.apply_dense(p["wk"], xk, dtype)))
    kv = M.apply_dense(p["wv"], k, dtype)
    out = jax.nn.sigmoid(M.apply_dense(p["wr"], xr, dtype)) * kv
    return out, state._replace(shift_cm=x[:, -1, :])


def init_rwkv_state(cfg, B: int, dtype) -> RWKVState:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.rwkv.head_dim
    return RWKVState(jnp.zeros((B, d), dtype), jnp.zeros((B, d), dtype),
                     jnp.zeros((B, H, hd, hd), jnp.float32))
