"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemv(w, x):
    """w (N,K), x (K,) -> (N,) fp32 accumulation."""
    return jnp.dot(w.astype(jnp.float32), x.astype(jnp.float32))


def dotp(x, y):
    return jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))


def axpy(a, x, y):
    return (a * x.astype(jnp.float32) + y.astype(jnp.float32)).astype(y.dtype)


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r * scale.astype(jnp.float32)).astype(x.dtype)


def fused_adamw(p, g, mu, nu, *, lr, b1, b2, eps, wd, bc1, bc2):
    g = g.astype(jnp.float32)
    mu = b1 * mu + (1 - b1) * g
    nu = b2 * nu + (1 - b2) * g * g
    upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
    p32 = p.astype(jnp.float32)
    p32 = p32 - lr * (upd + wd * p32)
    return p32.astype(p.dtype), mu, nu


def decode_attention(q, k, v, length):
    """q (B,H,hd); k,v (B,S,KV,hd); length (B,) valid prefix. -> (B,H,hd).

    GQA flash-decode oracle: full softmax over the valid prefix.
    """
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    mask = jnp.arange(S)[None, None, None, :] >= length[:, None, None, None]
    scores = jnp.where(mask, -jnp.inf, scores)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, hd)


def paged_decode_attention(q, k_pool, v_pool, block_tables, length):
    """Paged flash-decode oracle: gather the block table to the dense
    logical view, then the dense decode_attention oracle."""
    B, nblk = block_tables.shape
    page, KV, hd = k_pool.shape[1:]
    k = k_pool[block_tables].reshape(B, nblk * page, KV, hd)
    v = v_pool[block_tables].reshape(B, nblk * page, KV, hd)
    return decode_attention(q, k, v, length)


def decode_attention_int8(q, k8, k_scale, v8, v_scale, length):
    """Quantized flash-decode oracle: dequantize the int8 cache (values *
    per-(token, head) scale), then the dense decode_attention oracle."""
    k = k8.astype(jnp.float32) * k_scale.astype(jnp.float32)
    v = v8.astype(jnp.float32) * v_scale.astype(jnp.float32)
    return decode_attention(q, k, v, length)


def paged_decode_attention_int8(q, k_pool, k_scales, v_pool, v_scales,
                                block_tables, length):
    """Quantized paged oracle: gather value AND scale pages through the
    block table, dequantize the logical view, then the dense oracle."""
    B, nblk = block_tables.shape
    page, KV, hd = k_pool.shape[1:]
    k = (k_pool[block_tables].astype(jnp.float32)
         * k_scales[block_tables].astype(jnp.float32))
    v = (v_pool[block_tables].astype(jnp.float32)
         * v_scales[block_tables].astype(jnp.float32))
    return decode_attention(q, k.reshape(B, nblk * page, KV, hd),
                            v.reshape(B, nblk * page, KV, hd), length)


def prefill_attention_paged(q, k_pool, v_pool, block_tables, q_offset,
                            length):
    """Chunked-prefill paged-attention oracle: gather the block table to
    the dense logical view, then causal softmax attention with the slab's
    absolute query offset (positions >= length masked)."""
    B, C, H, hd = q.shape
    nblk = block_tables.shape[1]
    page, KV = k_pool.shape[1], k_pool.shape[2]
    S = nblk * page
    G = H // KV
    k = k_pool[block_tables].reshape(B, S, KV, hd).astype(jnp.float32)
    v = v_pool[block_tables].reshape(B, S, KV, hd).astype(jnp.float32)
    qg = q.reshape(B, C, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k) * (hd ** -0.5)
    qpos = q_offset[:, None] + jnp.arange(C)[None, :]          # (B, C)
    spos = jnp.arange(S)[None, :]                              # (B, S)
    mask = (spos[:, None, :] > qpos[:, :, None]) \
        | (spos[:, None, :] >= length[:, None, None])          # (B, C, S)
    s = jnp.where(mask[:, None, None], -jnp.inf, s)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)        # fully masked pad rows
    o = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return o.reshape(B, C, H, hd)


def qgemv(wq, scales, x, *, bits: int = 8):
    """Fused-dequant GEMV oracle: grouped dequant then fp32 GEMV.
    ``bits`` is explicit (4 = nibble-packed along K), never inferred."""
    from repro.quant.tensor import dequantize_values
    w = dequantize_values(wq, scales, axis=-1, bits=bits)
    return jnp.dot(w, x.astype(jnp.float32).T).T


def batched_qgemv(wq, scales, xs, *, bits: int = 8):
    """xs (B, K) -> (B, N): same oracle, batch on the lane dim."""
    return qgemv(wq, scales, xs, bits=bits)


def _mx_dequant(wq, scales):
    """Stored-layout MX dequant: (K | K//2-packed, N) codes + (K//g, N)
    E8M0 -> (K, N) fp32.  fp4 vs fp8 discriminated by dtype."""
    from repro.quant.tensor import dequantize_values
    bits = 4 if jnp.dtype(wq.dtype) == jnp.dtype(jnp.uint8) else 8
    return dequantize_values(wq, scales, axis=-2, bits=bits, fmt="mx")


def mx_qgemv(wq, scales, x):
    """MX GEMV oracle: block-exponent dequant then fp32 GEMV."""
    return jnp.dot(x.astype(jnp.float32), _mx_dequant(wq, scales))


def batched_mx_qgemv(wq, scales, xs):
    """xs (B, K) -> (B, N): same oracle, batch on the sublane dim."""
    return mx_qgemv(wq, scales, xs)


def mx_qgemv_swiglu(wg, sg, wu, su, x):
    """Fused MX swiglu oracle: silu(wg.T x) * (wu.T x), all fp32."""
    g = mx_qgemv(wg, sg, x)
    u = mx_qgemv(wu, su, x)
    return g * jax.nn.sigmoid(g) * u


def grouped_expert_qgemv(wq, scales, xs, expert_ids):
    """Dequantize-then-einsum oracle: gather the selected experts, dequant
    the full stack, one GEMV per (token-slot, expert) row."""
    w = _mx_dequant(wq, scales)                      # (E, K, N) fp32
    wsel = jnp.take(w, expert_ids, axis=0)           # (topk, K, N)
    return jnp.einsum("tk,tkn->tn", xs.astype(jnp.float32), wsel)


def flash_attention(q, k, v, causal=True):
    """q (B,T,H,hd), k/v (B,S,KV,hd) -> (B,T,H,hd). fp32 softmax oracle."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    if causal:
        mask = jnp.arange(T)[:, None] < jnp.arange(S)[None, :]
        s = jnp.where(mask[None, None, None], -jnp.inf, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, hd)


def wkv6(r, k, v, w, u, state0):
    """RWKV-6 recurrence oracle — re-exported from the model (lax.scan)."""
    from repro.models.rwkv import wkv6_scan
    return wkv6_scan(r, k, v, w, u, state0)


def mamba_scan(x, dt, B, C, A, D, state0):
    """Selective-scan oracle — re-exported from the model (lax.scan)."""
    from repro.models.mamba import _ssm_scan
    return _ssm_scan(x, dt, B, C, A, D, state0)
