"""Tree-level sharding helpers for the launcher (rules live in
``repro.core.partitioning``)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.partitioning import (DECODE_RULES, TRAIN_RULES, mesh_size,
                                     spec_for, wide_tp_rules)
from repro.models import modules as M

__all__ = ["TRAIN_RULES", "DECODE_RULES", "wide_tp_rules", "spec_for",
           "shardings_for_tree", "sds_with_sharding", "batch_spec",
           "cache_sharding"]


def shardings_for_tree(boxed, mesh: Mesh, rules):
    def one(p):
        return NamedSharding(mesh, spec_for(p.axes, p.value.shape, rules, mesh))
    return jax.tree.map(one, boxed, is_leaf=M.is_param)


def sds_with_sharding(boxed, mesh: Mesh, rules):
    def one(p):
        spec = spec_for(p.axes, p.value.shape, rules, mesh)
        return jax.ShapeDtypeStruct(p.value.shape, p.value.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(one, boxed, is_leaf=M.is_param)


def batch_spec(mesh: Mesh, rules) -> P:
    ax = rules.get("batch")
    if isinstance(ax, tuple):
        ax = tuple(a for a in ax if a in mesh.axis_names) or None
    return P(ax)


def cache_sharding(caches_sds, mesh: Mesh, rules, batch: int):
    """Decode-cache shardings: batch dim + (large) cache-seq dim.

    Leaves are (B, S, ...) KV tensors, (B, ...) recurrent states, or stacked
    (layers, B, ...) variants.
    """
    b_ax = rules.get("batch")
    if isinstance(b_ax, tuple):
        b_ax = tuple(a for a in b_ax if a in mesh.axis_names) or None
    s_ax = rules.get("cache_seq")
    if s_ax is not None and s_ax not in mesh.axis_names:
        s_ax = None

    def one(leaf):
        shape = leaf.shape
        entries = [None] * len(shape)
        bi = 0
        if len(shape) >= 2 and shape[0] != batch and shape[1] == batch:
            bi = 1
        bsz = mesh_size(b_ax, mesh)
        if shape[bi] == batch and bsz > 1 and batch % bsz == 0:
            entries[bi] = b_ax
        si = bi + 1
        ssz = mesh_size(s_ax, mesh)
        if len(shape) >= si + 2 and s_ax and ssz > 1 \
                and shape[si] % ssz == 0 and shape[si] >= 1024:
            entries[si] = s_ax
        while entries and entries[-1] is None:
            entries.pop()
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, caches_sds)
