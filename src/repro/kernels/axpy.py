"""AXPY kernel — y <- a*x + y (the paper's 3:1 bandwidth-to-compute kernel).

Three memory streams per FMA (read x, read y, write y): on the paper's 2:1
machine the bound is 66% FPU utilization; on TPU the op is pure bandwidth.
``streams=2`` splits x and y into contiguous halves (4 input DMAs in
flight); ``unroll`` mirrors the paper's §IV-F loop unrolling which breaks
the store->compute chaining dependency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel


def _example(small: bool = True):
    n = 4096 if small else 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.bfloat16)
    y = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.bfloat16)
    return (1.5, x, y), {}


def _kernel_1s(a_ref, x_ref, y_ref, o_ref):
    a = a_ref[0]
    o_ref[...] = (a * x_ref[...].astype(jnp.float32)
                  + y_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _kernel_2s(a_ref, x0, x1, y0, y1, o0, o1):
    a = a_ref[0]
    o0[...] = (a * x0[...].astype(jnp.float32)
               + y0[...].astype(jnp.float32)).astype(o0.dtype)
    o1[...] = (a * x1[...].astype(jnp.float32)
               + y1[...].astype(jnp.float32)).astype(o1.dtype)


@troop_kernel(
    "axpy",
    flops=lambda a, x, y: 2.0 * x.shape[0],
    bytes=lambda a, x, y: x.shape[0] * (itemsize(x) + 2 * itemsize(y)),
    streamed=lambda a, x, y: [x, y, y],      # y read + y-shaped result out
    space={"streams": (1, 2), "unroll": (1, 2),
           "block_k": (256, 512, 1024)},
    ref="axpy", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def axpy(a, x, y, cfg: TroopConfig = TroopConfig()):
    """a scalar, x/y (K,) -> a*x + y (dtype of y)."""
    K = x.shape[0]
    lanes = 128
    a = jnp.asarray(a, jnp.float32).reshape(1)
    x2, y2 = x.reshape(-1, lanes), y.reshape(-1, lanes)
    rows = x2.shape[0]
    br = max(min(cfg.block_k * cfg.unroll // lanes, rows // cfg.streams), 1)

    if cfg.streams == 1:
        while rows % br:
            br //= 2
        out = pl.pallas_call(
            _kernel_1s,
            grid=(rows // br,),
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                      pl.BlockSpec((br, lanes), lambda j: (j, 0)),
                      pl.BlockSpec((br, lanes), lambda j: (j, 0))],
            out_specs=pl.BlockSpec((br, lanes), lambda j: (j, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, lanes), y.dtype),
            interpret=cfg.interpret,
        )(a, x2, y2)
        return out.reshape(K)

    half = rows // 2
    while half % br:
        br //= 2
    steps = half // br
    out0, out1 = pl.pallas_call(
        _kernel_2s,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((br, lanes), lambda j: (j, 0)),
            pl.BlockSpec((br, lanes), lambda j, o=steps: (j + o, 0)),
            pl.BlockSpec((br, lanes), lambda j: (j, 0)),
            pl.BlockSpec((br, lanes), lambda j, o=steps: (j + o, 0)),
        ],
        out_specs=[pl.BlockSpec((br, lanes), lambda j: (j, 0)),
                   pl.BlockSpec((br, lanes), lambda j: (j, 0))],
        out_shape=[jax.ShapeDtypeStruct((half, lanes), y.dtype),
                   jax.ShapeDtypeStruct((half, lanes), y.dtype)],
        interpret=cfg.interpret,
    )(a, x2, x2, y2, y2)
    return jnp.concatenate([out0, out1], axis=0).reshape(K)
