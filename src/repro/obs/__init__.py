"""repro.obs — observability: lifecycle tracing, load harness, telemetry.

Four layers over the serving stack (DESIGN.md §7, §9):

  tracer    — ring-buffer ``Tracer``: per-request spans + allocator events
              + counters, exported as JSON-lines or Chrome trace-event
              format (opens in Perfetto, one track per engine slot)
  workload  — seeded replayable traces (bursty / diurnal / heavy-tail
              arrival + length distributions) and ``Replayer``, which
              drives any engine config against the arrival schedule and
              reports TTFT/TPOT percentiles, queue/occupancy timelines and
              defer/eviction counts — deterministic under the step clock
  energy    — ``decode_step_account`` + ``EnergyModel``: joins the tune
              registry's byte/FLOP models, the Spatz machine point and the
              Table-II energy constants into modeled joules/token,
              tokens/s/W and fraction-of-roofline per engine row
  profiler  — ``DispatchProfiler`` on the registry dispatch seam: per-
              (kernel, phase, signature) dispatch counts, modeled bytes,
              achieved bytes/s vs the Spatz roofline, Perfetto kernel
              spans + streamed-bytes counters, and the measured-vs-modeled
              ``audit_decode_step`` invariant (DESIGN.md §9)

Quickstart::

    from repro import obs
    tracer = obs.Tracer()
    eng = ServingEngine(..., tracer=tracer)
    trace = obs.generate("heavy_tail", requests=64, seed=0)
    report = obs.Replayer(eng).run(trace, vocab_size=cfg.vocab_size)
    tracer.to_chrome("soak.trace.json")      # open in ui.perfetto.dev
    print(report.row())                      # ttft_steps_p99, ...
"""
from repro.obs.energy import (AccountEntry, E_BEAT, E_FMA, EnergyModel,
                              P_STATIC, StepReport, account_totals,
                              decode_step_account, engine_energy_row)
from repro.obs.profiler import (AuditResult, DispatchProfiler,
                                DispatchRecord, audit_decode_step,
                                modeled_time_s, roofline_bytes_per_s)
from repro.obs.replay import Replayer, ReplayReport, percentiles
from repro.obs.tracer import Tracer, span_pairs
from repro.obs.workload import (DISTRIBUTIONS, TraceEntry, WorkloadTrace,
                                generate)

__all__ = [
    "Tracer", "span_pairs",
    "DISTRIBUTIONS", "TraceEntry", "WorkloadTrace", "generate",
    "Replayer", "ReplayReport", "percentiles",
    "AccountEntry", "EnergyModel", "StepReport", "account_totals",
    "decode_step_account", "engine_energy_row",
    "P_STATIC", "E_BEAT", "E_FMA",
    "DispatchProfiler", "DispatchRecord", "AuditResult",
    "audit_decode_step", "modeled_time_s", "roofline_bytes_per_s",
]
