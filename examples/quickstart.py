"""Quickstart: train a tiny LM for a few steps, then serve it.

    PYTHONPATH=src python examples/quickstart.py [--steps 30]

Exercises the full public API on CPU in ~a minute: config -> model ->
fault-tolerant trainer -> continuous-batching serving engine.
"""
import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models import RuntimeConfig, build_model
from repro.optim import OptConfig
from repro.serve import EngineConfig, Request, build_engine
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=512, num_heads=4, num_kv_heads=4,
                  head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    print(f"model: {cfg.name}  params={cfg.param_count():,}")

    trainer = Trainer(
        model, OptConfig(lr=1e-3, warmup_steps=10),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8),
        TrainerConfig(total_steps=args.steps, ckpt_every=10,
                      ckpt_dir="/tmp/repro_quickstart", log_every=5,
                      async_ckpt=False))
    params, _, hist = trainer.run()
    print("loss:", " -> ".join(f"{m['loss']:.3f}" for m in hist))

    engine = build_engine(model, EngineConfig(slots=2, cache_len=48),
                          params=params)
    for i in range(3):
        engine.submit(Request(rid=i, prompt=np.arange(1, 6 + i) % 500,
                              max_new_tokens=8))
    engine.run_until_drained()
    print("served 3 requests in", engine.steps, "decode steps")


if __name__ == "__main__":
    main()
