"""Serving-level roofline + energy attribution (the paper's Table II, per
engine row instead of per kernel).

Joins three things the repo already has:

  * the tune registry's per-kernel cost models (``flops=`` / ``bytes=`` /
    ``streamed=`` — audited registry-wide: modeled bytes == the sum of the
    operands the kernel actually streams),
  * the Spatz machine parameters of ``core.perfmodel`` (beats, memory
    beats/cycle, issue overhead — the cycle model's roofline terms), and
  * the energy constants fit for ``benchmarks/table2_energy.py`` (static
    power per cycle, energy per 256-bit TCDM beat, energy per FMA beat —
    calibrated once on the paper's dp-fdotp 25.9 DP-GFLOPs/W entry).

``decode_step_account`` enumerates the registry kernels one engine decode
step executes at given serving shapes (projections, paged attention, norms,
lm head — as ``ShapeDtypeStruct`` placeholders, nothing allocated), and
``EnergyModel.step_report`` folds the account into modeled cycles, energy,
joules/token, tokens/s/W and fraction-of-roofline — the serving analog of
the paper's 38 DP-GFLOPs/W headline, deterministic and CI-gateable.

Byte-model convention: weight/pool operands are exact; per-slot activation
vectors are counted once (not ``slots`` times) — at OI~=1 the streamed
weights and KV pages dominate, and keeping each entry's bytes equal to its
registry model preserves the audit identity (tested in ``test_obs``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
from jax import ShapeDtypeStruct as sds

from repro.core import perfmodel as PM

# per-cycle / per-event energies (pJ), 12nm-scale; fit once on the paper's
# Spatz_BASELINE dp-fdotp entry (25.9 DP-GFLOPs/W @ 1 GHz) and held fixed.
# ``benchmarks/table2_energy.py`` imports these — one set of constants.
P_STATIC = 36.0          # cluster overhead per cycle
E_BEAT = 70.0            # TCDM access + interconnect per 256-bit beat
E_FMA = 56.0             # 4x 64-bit FMA per beat

BEAT_BYTES = 32          # one 256-bit beat
FLOPS_PER_BEAT = 8       # 4 FMAs (64-bit lanes) per beat


@dataclass(frozen=True)
class AccountEntry:
    """One registry-kernel invocation class within a step."""
    kernel: str
    args: Tuple                  # ShapeDtypeStruct placeholders
    calls: int = 1
    tag: str = ""                # attribution label (attn / mlp / head / ...)


def _registry():
    import repro.kernels   # noqa: F401  (populates the registry)
    import repro.quant     # noqa: F401  (qgemv / int8 decode entries)
    from repro.tune.registry import REGISTRY
    return REGISTRY


def decode_step_account(model_cfg, *, slots: int, cache_len: int,
                        page_size: int = 16,
                        kv_dtype: str = "bfloat16",
                        weights: str = "bfloat16",
                        quant_group: int = 128) -> List[AccountEntry]:
    """Registry-kernel account of ONE decode step at the serving shapes.

    Covers the causal-attention decoder path the chunked engine serves:
    per layer 2 norms, QKV/O projections, paged decode attention over the
    full block table (worst-case context = ``cache_len``), the MLP (or the
    routed+shared experts of a MoE layer), plus final norm + lm head.
    ``kv_dtype="int8"`` switches the attention entry to the int8 paged
    kernel (scale pages included); ``weights="int8"`` routes projections
    through ``qgemv`` (value + scale traffic); ``weights="mx4"``/``"fp8"``
    routes them through the MX kernels — ``mx_qgemv`` projections, one
    fused ``mx_qgemv_swiglu`` per swiglu MLP half-pair, and
    ``grouped_expert_qgemv`` for the quantized MoE expert stacks (the
    path-policy flip: experts quantize under MX) — mirroring exactly what
    ``kernel_routing`` dispatches, so the audit stays byte-exact.
    """
    from repro.serve.kvcache import PageSpec

    REG = _registry()
    cfg = model_cfg
    B, d = slots, cfg.d_model
    H, KV, hd, V = (cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                    cfg.vocab_size)
    dt = jnp.dtype(cfg.dtype)
    mx = weights in ("mx4", "fp8")
    if kv_dtype == "int8":
        # int8 pages obey the coarser 32-row layout granule (mechanism D)
        from repro.quant.tensor import granule
        page_size = -(-page_size // granule()) * granule()
    spec = PageSpec.for_engine(slots, cache_len, page_size, None, kv_dtype)
    P, page, nblk = spec.num_pages, spec.page_size, spec.blocks_per_slot

    def mx_w(n_in: int, n_out: int, stack: int = 0):
        """(values, scales) placeholders of one MX weight, mirroring
        ``quantize_mx``: packed fp4 when the extent nibble-packs (mx4),
        fp8 otherwise; 32-row E8M0 blocks, collapsing on non-dividing
        extents."""
        from repro.quant.tensor import FP8_DTYPE, granule
        g = granule() if n_in % granule() == 0 else n_in
        lead = (stack,) if stack else ()
        if weights == "mx4" and n_in % 2 == 0:
            vals = sds(lead + (n_in // 2, n_out), jnp.uint8)
        else:
            vals = sds(lead + (n_in, n_out), FP8_DTYPE)
        return vals, sds(lead + (n_in // g, n_out), jnp.uint8)

    def proj(n_out: int, n_in: int, tag: str, calls: int = 1,
             raw: bool = False) -> AccountEntry:
        if mx and not raw:
            return AccountEntry(
                "mx_qgemv", (*mx_w(n_in, n_out), sds((n_in,), dt)),
                calls, tag)
        if weights == "int8" and not raw:
            g = quant_group if n_in % quant_group == 0 else n_in
            return AccountEntry(
                "qgemv", (sds((n_out, n_in), jnp.int8),
                          sds((n_out, n_in // g), jnp.float32),
                          sds((n_in,), dt)), calls, tag)
        return AccountEntry(
            "gemv", (sds((n_out, n_in), dt), sds((n_in,), dt)), calls, tag)

    def mx_swiglu(n_in: int, n_out: int, tag: str) -> AccountEntry:
        vg, sg = mx_w(n_in, n_out)
        return AccountEntry(
            "mx_qgemv_swiglu", (vg, sg, vg, sg, sds((n_in,), dt)), 1, tag)

    def mx_grouped(E: int, topk: int, n_in: int, n_out: int,
                   calls: int = 1) -> AccountEntry:
        return AccountEntry(
            "grouped_expert_qgemv",
            (*mx_w(n_in, n_out, stack=E), sds((topk, n_in), dt),
             sds((topk,), jnp.int32)), calls, "moe")

    def attn_entry() -> AccountEntry:
        if kv_dtype == "int8":
            return AccountEntry(
                "paged_decode_attention_int8",
                (sds((B, H, hd), dt),
                 sds((P, page, KV, hd), jnp.int8),
                 sds((P, page, KV, 1), jnp.bfloat16),
                 sds((P, page, KV, hd), jnp.int8),
                 sds((P, page, KV, 1), jnp.bfloat16),
                 sds((B, nblk), jnp.int32), sds((B,), jnp.int32)),
                1, "attn")
        return AccountEntry(
            "paged_decode_attention",
            (sds((B, H, hd), dt),
             sds((P, page, KV, hd), dt), sds((P, page, KV, hd), dt),
             sds((B, nblk), jnp.int32), sds((B,), jnp.int32)),
            1, "attn")

    norm = AccountEntry("rmsnorm", (sds((B, d), dt),
                                    sds((d,), jnp.float32)), 1, "norm")
    entries: List[AccountEntry] = []
    for mixer, ffn in cfg.layer_kinds():
        if mixer != "attn":
            raise ValueError(
                f"decode_step_account models causal-attention decoder "
                f"archs (the chunked engine's domain); {cfg.name!r} has a "
                f"{mixer!r} mixer")
        entries.append(norm)                                  # pre-attn
        entries.append(proj(H * hd, d, "attn_proj"))          # W_Q
        entries.append(proj(KV * hd, d, "attn_proj", calls=2))  # W_K, W_V
        entries.append(attn_entry())
        entries.append(proj(d, H * hd, "attn_proj"))          # W_O
        entries.append(norm)                                  # pre-ffn
        mult = 3 if cfg.act == "swiglu" else 2
        if ffn == "moe":
            mo = cfg.moe
            # router logits are fp32 over raw weights ("router" is in
            # quant.params.EXCLUDE_KEYS), so the entry is an f32 gemv
            # regardless of the ``weights`` policy
            entries.append(AccountEntry(
                "gemv", (sds((mo.num_experts, d), jnp.float32),
                         sds((d,), jnp.float32)), 1, "router"))
            if mx:
                # path-policy flip: MX expert stacks dispatch per router
                # selection through the grouped kernel (one call per
                # projection, the top-k ids scalar-prefetched)
                E, k = mo.num_experts, mo.num_experts_per_tok
                entries.append(mx_grouped(E, k, d, mo.d_ff,
                                          calls=mult - 1))
                entries.append(mx_grouped(E, k, mo.d_ff, d))
            else:
                entries.append(proj(
                    mo.d_ff, d, "moe",
                    calls=mo.num_experts_per_tok * (mult - 1)))
                entries.append(proj(d, mo.d_ff, "moe",
                                    calls=mo.num_experts_per_tok))
            if mo.shared_d_ff:
                if mx and mult == 3:
                    entries.append(mx_swiglu(d, mo.shared_d_ff, "moe"))
                else:
                    entries.append(proj(mo.shared_d_ff, d, "moe",
                                        calls=mult - 1))
                entries.append(proj(d, mo.shared_d_ff, "moe"))
                if mo.shared_expert_gate:
                    # "shared_gate" is outside quant.params.QUANTIZE_KEYS:
                    # raw under MX (the byte-exact audit sees a plain gemv)
                    entries.append(proj(1, d, "moe", raw=mx))
        else:
            if mx and mult == 3:
                entries.append(mx_swiglu(d, cfg.d_ff, "mlp"))
            else:
                entries.append(proj(cfg.d_ff, d, "mlp", calls=mult - 1))
            entries.append(proj(d, cfg.d_ff, "mlp"))
    entries.append(norm)                                      # final norm
    # tied read-out goes through the raw embed table (embeds never
    # quantize); a separate lm_head quantizes with the projections
    entries.append(proj(V, d, "head", raw=mx and cfg.tie_embeddings))
    for e in entries:
        if e.kernel not in REG:
            raise KeyError(f"account kernel {e.kernel!r} not registered")
    return entries


def account_totals(entries: List[AccountEntry]) -> Dict[str, float]:
    """Fold an account through the registry cost models: total modeled
    bytes and FLOPs (the audit quantities)."""
    REG = _registry()
    total_bytes = total_flops = 0.0
    for e in entries:
        spec = REG[e.kernel]
        total_bytes += spec.bytes(*e.args) * e.calls
        total_flops += spec.flops(*e.args) * e.calls
    return {"bytes": total_bytes, "flops": total_flops,
            "kernels": sum(e.calls for e in entries)}


@dataclass
class StepReport:
    """Modeled cost of one decode step (Spatz cycle terms + energy)."""
    bytes: float
    flops: float
    mem_beats: float
    flop_beats: float
    cycles: float
    energy_pj: float
    tokens_per_step: float     # fractional under speculation (k * acceptance)
    fraction_of_roofline: float
    per_kernel: List[Dict] = field(default_factory=list)

    @property
    def joules_per_token(self) -> float:
        return self.energy_pj * 1e-12 / max(self.tokens_per_step, 1)

    @property
    def tokens_per_s_per_w(self) -> float:
        """tokens/J == tokens/s per watt (unit identity)."""
        j = self.joules_per_token
        return 1.0 / j if j else 0.0

    def row(self) -> Dict:
        """Flat dict for BENCH JSON / ci_gate (ints exact-gateable)."""
        return {
            "modeled_bytes_per_step": int(self.bytes),
            "modeled_flops_per_step": int(self.flops),
            "modeled_cycles_per_step": round(self.cycles, 3),
            "tokens_per_step": round(self.tokens_per_step, 3),
            "bytes_per_token": int(self.bytes / max(self.tokens_per_step,
                                                    1)),
            "joules_per_token": self.joules_per_token,
            "tokens_per_s_per_w": self.tokens_per_s_per_w,
            "fraction_of_roofline": self.fraction_of_roofline,
        }


class EnergyModel:
    """Spatz-style roofline/energy fold over a kernel account.

    ``spatz``: the machine point (default: the paper's full TROOP config).
    cycles = max(mem_beats / mem_beats_per_cycle, flop_beats) +
    issue_overhead per kernel launch; roofline fraction = the memory-bound
    ideal over modeled cycles (OI~=1: the memory roofline IS the bound).
    E = cycles*P_STATIC + mem_beats*E_BEAT + flop_beats*E_FMA, the
    ``table2_energy`` formula applied to serving-step traffic.
    """

    def __init__(self, spatz: Optional[PM.SpatzConfig] = None):
        self.spatz = spatz if spatz is not None else PM.BW2X_TROOP

    def step_report(self, entries: List[AccountEntry],
                    tokens_per_step: float) -> StepReport:
        REG = _registry()
        cfg = self.spatz
        per_kernel: List[Dict] = []
        tot_b = tot_f = 0.0
        launches = 0
        agg: Dict[str, Dict] = {}
        for e in entries:
            spec = REG[e.kernel]
            b = spec.bytes(*e.args) * e.calls
            f = spec.flops(*e.args) * e.calls
            tot_b += b
            tot_f += f
            launches += e.calls
            a = agg.setdefault(e.kernel, {"kernel": e.kernel, "calls": 0,
                                          "bytes": 0.0, "flops": 0.0})
            a["calls"] += e.calls
            a["bytes"] += b
            a["flops"] += f
        mem_beats = tot_b / BEAT_BYTES
        flop_beats = tot_f / FLOPS_PER_BEAT
        mem_cycles = mem_beats / cfg.mem_beats_per_cycle
        cycles = max(mem_cycles, flop_beats) + launches * cfg.issue_overhead
        energy = cycles * P_STATIC + mem_beats * E_BEAT + \
            flop_beats * E_FMA
        for a in agg.values():
            share = a["bytes"] / tot_b if tot_b else 0.0
            per_kernel.append({**a, "bytes_share": round(share, 4)})
        per_kernel.sort(key=lambda r: -r["bytes"])
        return StepReport(
            bytes=tot_b, flops=tot_f, mem_beats=mem_beats,
            flop_beats=flop_beats, cycles=cycles, energy_pj=energy,
            tokens_per_step=tokens_per_step,
            fraction_of_roofline=mem_cycles / cycles if cycles else 0.0,
            per_kernel=per_kernel)


def engine_energy_row(model_cfg, *, slots: int, cache_len: int,
                      page_size: int = 16, kv_dtype: str = "bfloat16",
                      weights: str = "bfloat16", speculate_k: int = 0,
                      acceptance: float = 1.0,
                      spatz: Optional[PM.SpatzConfig] = None) -> Dict:
    """One BENCH-ready energy row for an engine config: account + fold.

    ``speculate_k`` > 0 models the speculative verify pass: the same
    weight/KV traffic as a decode step (at OI~=1 the k extra activation
    rows are noise next to the streamed weights and pages, and the byte
    convention counts activations once anyway) amortized over
    ``slots * (1 + k * acceptance)`` emitted tokens per target pass — the
    TROOP lever as a bytes/token ratio.  Draft-model traffic is excluded
    (the draft is a separate, much smaller account; the row prices the
    target stream only).
    """
    entries = decode_step_account(
        model_cfg, slots=slots, cache_len=cache_len, page_size=page_size,
        kv_dtype=kv_dtype, weights=weights)
    tokens = slots * (1 + speculate_k * acceptance)
    rep = EnergyModel(spatz).step_report(entries, tokens_per_step=tokens)
    row = {"arch": model_cfg.name, "kv_dtype": kv_dtype, "weights": weights,
           "slots": slots, "cache_len": cache_len, "page_size": page_size,
           **rep.row()}
    if speculate_k:
        row["speculate_k"] = speculate_k
        row["acceptance"] = acceptance
    row["per_kernel"] = rep.per_kernel
    return row
