"""Encoder-decoder model (whisper): bidirectional encoder + causal decoder
with per-layer cross-attention.  The conv/mel frontend is a STUB — the
encoder consumes precomputed frame embeddings (B, F, d_model) supplied by
``input_specs()`` per the assignment.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import modules as M
from repro.models import transformer as T


def encoder_cfg(cfg):
    return dataclasses.replace(
        cfg, num_layers=cfg.num_encoder_layers, encoder_decoder=False,
        moe=None, pos_emb="sinusoidal", name=cfg.name + "-enc")


def init_encdec(key, cfg):
    k_enc, k_dec = jax.random.split(key)
    ecfg = encoder_cfg(cfg)
    groups = T.plan_groups(ecfg)
    ks = jax.random.split(k_enc, len(groups) + 1)
    enc = {
        "groups": [T.init_group(ks[i], ecfg, g) for i, g in enumerate(groups)],
        "final_norm": M.norm_init(cfg.norm, cfg.d_model),
    }
    p = T.init_lm(k_dec, cfg)
    p["encoder"] = enc
    return p


def encode(params, cfg, rt, frames, dtype):
    """frames: (B, F, d_model) stub embeddings -> encoder states."""
    ecfg = encoder_cfg(cfg)
    groups = T.plan_groups(ecfg)
    x = frames.astype(dtype)
    x = x + M.sinusoidal_pos(x.shape[1], cfg.d_model).astype(dtype)
    B, F = x.shape[:2]
    positions = jnp.arange(F)[None, :]
    states = T._zero_states(ecfg, groups, B, dtype)
    x, _, _ = T._run_groups(params["encoder"]["groups"], groups, ecfg, rt, x,
                            positions=positions, states=states, dtype=dtype,
                            enc_kv="encoder")
    return M.apply_norm(params["encoder"]["final_norm"], x, cfg.norm,
                        cfg.norm_eps)


def train_logits(params, cfg, rt, batch):
    """batch: frontend (B,F,d) frames + tokens (B,T) decoder input."""
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, rt, batch["frontend"], dtype)
    groups = T.plan_groups(cfg)
    x = T.embed_inputs(params, cfg,
                       {k: v for k, v in batch.items() if k != "frontend"},
                       dtype)
    B, Tq = x.shape[:2]
    positions = jnp.arange(Tq)[None, :]
    states = T._zero_states(cfg, groups, B, dtype)
    x, _, aux = T._run_groups(params["groups"], groups, cfg, rt, x,
                              positions=positions, states=states,
                              dtype=dtype, enc_kv=enc_out)
    return T.readout(params, cfg, x, dtype), aux


def prefill(params, cfg, rt, batch):
    dtype = jnp.dtype(cfg.dtype)
    enc_out = encode(params, cfg, rt, batch["frontend"], dtype)
    groups = T.plan_groups(cfg)
    x = T.embed_inputs(params, cfg,
                       {k: v for k, v in batch.items() if k != "frontend"},
                       dtype)
    B, Tq = x.shape[:2]
    positions = jnp.arange(Tq)[None, :]
    states = T._zero_states(cfg, groups, B, dtype)
    x, caches, _ = T._run_groups(params["groups"], groups, cfg, rt, x,
                                 positions=positions, states=states,
                                 dtype=dtype, return_cache=True,
                                 enc_kv=enc_out)
    return T.readout(params, cfg, x, dtype), caches


decode_step = T.decode_step    # decoder decode; cross-KV rides in the cache
