"""Fig. 5 reproduction: FPU utilization per kernel per Spatz config,
from the cycle-level perfmodel, with deltas against the paper."""
from __future__ import annotations

import time

from repro.core import perfmodel as PM
from benchmarks.paper_data import DOTP_LONG, FIG5, SPEEDUPS


def run(csv=print):
    t0 = time.time()
    res = PM.figure5(4096)
    for kernel, row in res.items():
        for cfg_name, util in row.items():
            paper = FIG5.get(kernel, {}).get(cfg_name)
            note = "paper=n/a" if paper is None else \
                f"paper={paper * 100:.0f} delta={(util - paper) * 100:+.1f}"
            csv(f"fig5/{kernel}/{cfg_name},{util * 100:.1f},{note}")
    # long-vector DOTP (96% claim)
    for cfg_name in ("Spatz_2xBW", "Spatz_2xBW_TROOP"):
        u = PM.utilization("dotp", PM.CONFIGS[cfg_name], 65536).fpu_util
        csv(f"fig5/dotp_long/{cfg_name},{u * 100:.1f},"
            f"paper={DOTP_LONG[cfg_name] * 100:.0f}")
    # headline speedups
    for k, target in SPEEDUPS.items():
        sp = res[k]["Spatz_2xBW_TROOP"] / res[k]["Spatz_BASELINE"]
        csv(f"fig5/speedup/{k},{sp:.2f},paper={target}")
    csv(f"fig5/elapsed,{(time.time() - t0) * 1e6:.0f},us_total")


if __name__ == "__main__":
    run()
