"""Ring-buffer lifecycle tracer — per-request spans, events and counters.

The serving engine (``serve.scheduler``) and the paged cache backend
(``serve.kvcache``) feed a ``Tracer`` with the full life of every request
(submit -> admit/defer -> prefill slabs -> first token -> decode -> finish)
plus allocator events (page alloc/free, prefix hits, copy-on-write, LRU
eviction) and counter samples (queue depth, pool occupancy).  Recording is
a single tuple append into a bounded ``deque`` — cheap enough to leave on
during a soak — and ``None`` tracers cost one attribute check per site.

Two export formats:

  * ``to_jsonl``  — one event per line, trivially greppable/joinable.
  * ``to_chrome`` — Chrome trace-event JSON (``{"traceEvents": [...]}``)
    that opens directly in Perfetto / ``chrome://tracing``: one thread
    track per engine slot (request spans + chunk slabs), plus dedicated
    ``queue`` / ``allocator`` / ``engine`` tracks and counter tracks.

Timestamps are ``time.perf_counter()`` seconds relative to tracer creation
(exported as microseconds, the Chrome unit).
"""
from __future__ import annotations

import json
import time
from collections import Counter, deque
from typing import Any, Dict, Iterable, List, Optional, Tuple, Union

# event phases (Chrome trace-event vocabulary)
INSTANT = "i"
SPAN = "X"
COUNTER = "C"

Track = Union[int, str]          # int: engine slot; str: named track


class Tracer:
    """Bounded ring buffer of trace events.

    ``capacity`` bounds memory: once full, the oldest events are dropped
    (``dropped`` counts them) — a long-lived engine can trace forever and
    export the most recent window.
    """

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------ record
    def now(self) -> float:
        return time.perf_counter() - self._t0

    def rel(self, t_abs: float) -> float:
        """An absolute ``perf_counter`` stamp -> tracer-relative seconds."""
        return t_abs - self._t0

    def _push(self, evt: Tuple):
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(evt)

    def instant(self, name: str, track: Track, rid: Optional[int] = None,
                ts: Optional[float] = None, **args):
        """A point event (e.g. ``submit``, ``page_alloc``, ``evict``)."""
        self._push((ts if ts is not None else self.now(), INSTANT, name,
                    track, rid, 0.0, args or None))

    def span(self, name: str, track: Track, start: float, end: float,
             rid: Optional[int] = None, **args):
        """A complete [start, end) span (e.g. a request, a chunk slab)."""
        self._push((start, SPAN, name, track, rid, max(end - start, 0.0),
                    args or None))

    def counter(self, name: str, value, ts: Optional[float] = None):
        """A counter sample (queue depth, pages in use, ...)."""
        self._push((ts if ts is not None else self.now(), COUNTER, name,
                    name, None, 0.0, {"value": value}))

    # ------------------------------------------------------------ inspect
    def events(self, name: Optional[str] = None) -> List[Tuple]:
        """Snapshot of recorded events ``(ts, ph, name, track, rid, dur,
        args)``, oldest first; ``name`` filters."""
        evs = list(self._ring)
        if name is not None:
            evs = [e for e in evs if e[2] == name]
        return evs

    def counts(self) -> Dict[str, int]:
        """Event-name -> occurrence count (allocator balance checks)."""
        return dict(Counter(e[2] for e in self._ring))

    def sum_arg(self, name: str, key: str) -> float:
        """Sum ``args[key]`` over events called ``name`` (e.g. total pages
        allocated = ``sum_arg("page_alloc", "pages")``)."""
        return sum(e[6][key] for e in self._ring
                   if e[2] == name and e[6] and key in e[6])

    def clear(self):
        self._ring.clear()
        self.dropped = 0

    # ------------------------------------------------------------- export
    def to_jsonl(self, path: str):
        with open(path, "w") as f:
            for ts, ph, name, track, rid, dur, args in self._ring:
                rec = {"ts_us": ts * 1e6, "ph": ph, "name": name,
                       "track": track}
                if rid is not None:
                    rec["rid"] = rid
                if ph == SPAN:
                    rec["dur_us"] = dur * 1e6
                if args:
                    rec["args"] = args
                f.write(json.dumps(rec) + "\n")
            # trailing metadata record: a truncated ring is not a complete
            # trace, and consumers must be able to tell
            f.write(json.dumps({"ph": "M", "name": "dropped_events",
                                "dropped": self.dropped,
                                "capacity": self.capacity}) + "\n")

    def _tids(self) -> Dict[Track, int]:
        """Stable track -> tid map: slot ints keep their value (one track
        per slot, sorted first in Perfetto); named tracks follow."""
        slots = sorted({e[3] for e in self._ring if isinstance(e[3], int)})
        named = sorted({e[3] for e in self._ring
                        if isinstance(e[3], str) and e[1] != COUNTER})
        tids: Dict[Track, int] = {s: s for s in slots}
        base = (max(slots) + 1) if slots else 0
        for i, n in enumerate(named):
            tids[n] = base + 100 + i
        return tids

    def chrome_events(self, pid: int = 1) -> List[Dict[str, Any]]:
        tids = self._tids()
        out: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "name": "process_name",
             "args": {"name": "repro.serve"}},
            {"ph": "M", "pid": pid, "name": "dropped_events",
             "args": {"dropped": self.dropped,
                      "capacity": self.capacity}}]
        if self.dropped:
            # visible Perfetto counter: the exported window starts after
            # `dropped` older events fell off the ring
            first_ts = self._ring[0][0] if self._ring else 0.0
            out.append({"ph": COUNTER, "pid": pid, "name": "dropped_events",
                        "ts": first_ts * 1e6,
                        "args": {"value": self.dropped}})
        for track, tid in tids.items():
            label = f"slot {track}" if isinstance(track, int) else track
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name", "args": {"name": label}})
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_sort_index",
                        "args": {"sort_index": tid}})
        for ts, ph, name, track, rid, dur, args in self._ring:
            evt: Dict[str, Any] = {"ph": ph, "name": name, "pid": pid,
                                   "ts": ts * 1e6}
            if ph == COUNTER:
                evt["args"] = args
            else:
                evt["tid"] = tids.get(track, 0)
                evt["cat"] = "serve"
                a = dict(args) if args else {}
                if rid is not None:
                    a["rid"] = rid
                if a:
                    evt["args"] = a
                if ph == SPAN:
                    evt["dur"] = dur * 1e6
            out.append(evt)
        return out

    def to_chrome(self, path: str):
        """Write a Perfetto-loadable Chrome trace-event file."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.chrome_events(),
                       "displayTimeUnit": "ms"}, f)


def span_pairs(events: Iterable[Tuple], open_name: str,
               close_name: str) -> Tuple[Dict[int, float], Dict[int, float]]:
    """(rid -> open ts, rid -> close ts) over instant events — the test
    helper behind 'every admitted request has a closed span'."""
    opened: Dict[int, float] = {}
    closed: Dict[int, float] = {}
    for ts, ph, name, track, rid, dur, args in events:
        if rid is None:
            continue
        if name == open_name and rid not in opened:
            opened[rid] = ts
        elif name == close_name:
            closed[rid] = ts
    return opened, closed
