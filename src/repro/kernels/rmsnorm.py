"""Fused RMSNorm kernel (DOTP-class: one streaming pass, row-wise tree
reduction on the VPU + immediate scale — vs. the unfused reference which
reads x twice and materializes the square).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel


def _example(small: bool = True):
    T, d = (8, 256) if small else (256, 4096)
    x = jax.random.normal(jax.random.PRNGKey(0), (T, d), jnp.bfloat16)
    s = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    return (x, s), {}


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@troop_kernel(
    "rmsnorm",
    flops=lambda x, s, *a: 4.0 * x.shape[0] * x.shape[1],
    bytes=lambda x, s, *a: (2 * x.shape[0] * x.shape[1] * itemsize(x)
                            + x.shape[1] * itemsize(s)),
    streamed=lambda x, s, *a: [x, s, x],     # x in, scale, x-shaped out
    space={"block_n": (64, 128, 256)},
    ref="rmsnorm", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg", "eps"))
def rmsnorm(x, scale, eps: float = 1e-6, cfg: TroopConfig = TroopConfig()):
    """x (T, d), scale (d,) -> normalized x (dtype preserved)."""
    T, d = x.shape
    bt = max(min(cfg.block_n, T), 1)
    while T % bt:
        bt //= 2
    s2 = scale.reshape(1, d)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(T // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=cfg.interpret,
    )(x, s2)
