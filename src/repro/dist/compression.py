"""Int8 gradient compression (per-tensor absmax scale).

Used with error feedback on the data-parallel reduction: the quantization
residual is carried to the next step, so the *sum* of dequantized updates
converges to the sum of true gradients (tested as a hypothesis property).

Thin wrappers over the ``repro.quant`` primitives — one absmax
implementation serves gradients, KV caches and weights alike; the
error-feedback residual semantics in ``dist/ddp.py`` are unchanged.
"""
from __future__ import annotations

from repro.quant.tensor import dequantize_int8, quantize_int8  # noqa: F401

__all__ = ["quantize_int8", "dequantize_int8"]
