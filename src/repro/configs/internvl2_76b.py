"""internvl2-76b — VLM backbone (InternViT frontend is a STUB).

[arXiv:2404.16821; unverified]  80L d_model=8192 64H kv=8 d_ff=28672
vocab=128256.  ``input_specs()`` provides precomputed patch embeddings; the
vision tower itself is out of scope per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    frontend="vision",
    frontend_tokens=256,       # ViT patch embeddings prepended to the sequence
)
