"""Serving-engine benchmark -> table + BENCH_serve.json.

Runs the continuous-batching engine end to end in four modes — dense,
paged, chunked prefill, chunked + prefix cache (the last on a shared
system-prompt trace) — plus a speculative row (``speculative/k3``: a
same-arch seed-0 draft gives 100% greedy acceptance, so
``tokens_per_target_pass`` is deterministic, asserted > 1 and
exact-gated) on a reduced arch and reports decode steps/s,
tokens/s, per-request TTFT / decode rate, prefill-compile counts and
prefix-hit rates; then times the decode/prefill attention kernels (dense
and paged layouts) at the serving shapes and scores each as a measured
fraction-of-roofline (t_roofline / t_measured, tune subsystem
denominators).  Three extra chunked+prefix rows run the tensor-parallel
engine at tp=1/2/4 on a simulated 4-device host mesh — the modeled
per-device streamed-KV bytes are exact integers and gateable (a tp=4 row
must stream exactly 1/4 of the logical bytes per device).  One more
chunked+prefix row runs under a ``repro.obs.DispatchProfiler``
(mode ``chunked+prefix/profiled``): per-phase dispatch counts and modeled
bytes are deterministic and exact-gated.  ``--soak N`` adds an N-request
drain through the chunked+prefix engine (the nightly workload;
``--soak-tp 4`` adds a TP soak row; ``--soak-profile-trace PATH`` writes
the soak's Perfetto trace with per-kernel spans + streamed-bytes
counters); ``benchmarks/ci_gate.py`` gates the JSON against committed
baselines.

    PYTHONPATH=src python benchmarks/serve_bench.py --fast

Interpret-mode wall times on CPU are NOT TPU performance (see
DESIGN.md §3) — the value here is that the whole engine/kernel stack is
exercised for real and the numbers are comparable run over run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


MODES = ("dense", "paged", "chunked", "chunked+prefix")


def make_trace(cfg, rng, requests, max_new, *, shared_prefix=0):
    """Mixed-length prompt trace; ``shared_prefix`` > 0 prepends a common
    system prompt of that many tokens (the prefix-cache workload)."""
    import numpy as np
    from repro.serve.scheduler import Request
    head = rng.integers(1, min(cfg.vocab_size, 1000), shared_prefix) \
        if shared_prefix else None
    reqs = []
    for i in range(requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 1000),
                              int(rng.integers(4, 20)))
        if head is not None:
            prompt = np.concatenate([head, prompt])
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def bench_engine(arch: str, mode: str, *, slots, cache_len, requests,
                 max_new, page_size, chunk_size=16, tp=1, profiler=None,
                 speculate_k=0):
    import numpy as np
    from repro.configs import get_config, reduced
    from repro.serve import EngineConfig, build_engine

    cfg = reduced(get_config(arch))
    base = mode.split("/")[0]        # "chunked+prefix/tp4" -> "chunked+prefix"
    engine_cfg = EngineConfig(
        slots=slots, cache_len=cache_len,
        backend="dense" if base == "dense" else "paged",
        page_size=page_size,
        chunked_prefill=base.startswith("chunked") or speculate_k > 0,
        chunk_size=chunk_size, prefix_cache=(base == "chunked+prefix"),
        speculate_k=speculate_k, tp=tp)
    # same-arch draft with the factory's seed-0 params on both sides ->
    # 100% greedy acceptance: the speculative row is deterministic
    draft = reduced(get_config(arch)) if speculate_k else None
    eng = build_engine(cfg, engine_cfg, draft=draft, profiler=profiler)
    rng = np.random.default_rng(0)
    reqs = make_trace(cfg, rng, requests, max_new,
                      shared_prefix=24 if base == "chunked+prefix" else 0)
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    finished = eng.run_until_drained()
    wall = time.perf_counter() - t0
    m = eng.metrics()
    m.update({"arch": cfg.name, "mode": mode, "wall_s": wall,
              "requests_submitted": requests,
              "all_finished": len(finished) == requests})
    return m


def bench_profiled_engine(arch: str, *, slots, cache_len, requests,
                          max_new, page_size, chunk_size=16):
    """chunked+prefix engine run under a ``DispatchProfiler``: the engine
    row plus per-phase dispatch counts / modeled bytes (deterministic —
    exact CI gates) and wall-derived roofline fractions (info)."""
    from repro.configs import get_config, reduced
    from repro.obs import DispatchProfiler, decode_step_account

    cfg = reduced(get_config(arch))
    prof = DispatchProfiler()
    prof.seed_phase("decode", decode_step_account(
        cfg, slots=slots, cache_len=cache_len, page_size=page_size))
    prof.install()
    try:
        m = bench_engine(arch, "chunked+prefix/profiled", slots=slots,
                         cache_len=cache_len, requests=requests,
                         max_new=max_new, page_size=page_size,
                         chunk_size=chunk_size, profiler=prof)
    finally:
        prof.uninstall()
    m["profile"] = prof.phase_rows()
    return m


def bench_soak(arch: str, *, requests, slots, cache_len, page_size,
               chunk_size=16, tp=1, profile_trace=None, speculate_k=0):
    """N-request heavy-tail soak through the chunked+prefix engine under
    the deterministic step clock (``repro.obs``): percentile latency rows
    (engine cycles, gateable; wall seconds, info) plus queue-depth /
    occupancy timelines.  ``tp`` > 1 drains the same trace through the
    tensor-parallel engine (the nightly TP row).  ``profile_trace`` runs
    the soak under a ``DispatchProfiler`` feeding a ``Tracer`` and writes
    the Chrome trace (per-kernel spans + streamed-bytes counters) there."""
    from repro import obs
    _here = os.path.dirname(os.path.abspath(__file__))
    if _here not in sys.path:
        sys.path.insert(0, _here)
    from load_bench import build_engine

    tracer = prof = None
    if profile_trace:
        from repro.configs import get_config, reduced
        tracer = obs.Tracer()
        prof = obs.DispatchProfiler(tracer=tracer)
        prof.seed_phase("decode", obs.decode_step_account(
            reduced(get_config(arch)), slots=slots, cache_len=cache_len,
            page_size=page_size))
        prof.install()
    base = "speculative" if speculate_k else "chunked+prefix"
    cfg, eng = build_engine(arch, base, slots=slots,
                            cache_len=cache_len, page_size=page_size,
                            chunk_size=chunk_size, tracer=tracer,
                            profiler=prof, tp=tp, speculate_k=speculate_k)
    trace = obs.generate("heavy_tail", requests=requests, seed=0,
                         prompt_len=(4, min(48, cache_len - 18)),
                         max_new=(2, 16))
    try:
        rep = obs.Replayer(eng, timeline_every=4).run(
            trace, vocab_size=cfg.vocab_size)
    finally:
        if prof is not None:
            prof.uninstall()
    mode = f"soak/{base}" + (f"/k{speculate_k}" if speculate_k else "") \
        + (f"/tp{tp}" if tp > 1 else "")
    row = {"arch": cfg.name, "mode": mode,
           "dist": "heavy_tail", **rep.row()}
    if speculate_k:
        em = eng.metrics()
        row.update({k: em[k] for k in
                    ("speculate_k", "acceptance_rate",
                     "tokens_per_target_pass", "rollback_pages")})
    if profile_trace:
        tracer.to_chrome(profile_trace)
        print(f"wrote {profile_trace} ({len(tracer.events())} events, "
              f"{tracer.dropped} dropped)")
        row["profile"] = prof.phase_rows()
    tl = rep.timeline
    row["timeline"] = {k: [float(x) for x in tl[k]]
                       for k in ("t", "queue_depth", "decoding",
                                 "pages_in_use") if k in tl}
    return row


def bench_decode_kernels(*, slots, cache_len, page_size, iters):
    """Dense vs paged decode-attention at the serving shapes."""
    import jax
    import jax.numpy as jnp
    import repro.kernels  # noqa: F401  (populates the registry)
    from repro.tune import REGISTRY
    from repro.tune.cache import get_tuned
    from repro.tune.search import measure, roofline_time

    B, S, page = slots, cache_len, page_size
    KV, H, hd = 2, 4, 64
    nblk = -(-S // page)
    P = B * nblk + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.bfloat16)
    length = jnp.full((B,), S - 1, jnp.int32)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), jnp.bfloat16)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), jnp.bfloat16)
    import numpy as np
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)

    C = 16
    qc = jax.random.normal(ks[0], (B, C, H, hd), jnp.bfloat16)
    q_off = jnp.zeros((B,), jnp.int32)
    clen = jnp.full((B,), C, jnp.int32)
    cases = {
        "decode_attention": (q, k, v, length),
        "paged_decode_attention": (q, k_pool, v_pool, bt, length),
        "prefill_attention_paged": (qc, k_pool, v_pool, bt, q_off, clen),
    }
    rows = []
    for name, args in cases.items():
        spec = REGISTRY[name]
        cfg = get_tuned(name, *args)
        t = measure(spec, cfg, args, iters=iters)
        roof = roofline_time(spec, args)
        rows.append({
            "kernel": name,
            "shape": f"B={B} S={S} KV={KV} H={H} hd={hd}"
                     + (f" page={page}" if "paged" in name else ""),
            "measured_us": t * 1e6,
            "roofline_us": roof * 1e6,
            "fraction_of_roofline": roof / t if t else 0.0,
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--fast", action="store_true",
                    help="fewer requests / timing iterations (CI smoke)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--soak", type=int, default=0, metavar="N",
                    help="also run an N-request mixed-length drain through "
                         "the chunked+prefix engine (the nightly soak)")
    ap.add_argument("--soak-tp", type=int, default=0, metavar="TP",
                    help="with --soak: add one more soak row through the "
                         "tensor-parallel engine at this tp size")
    ap.add_argument("--soak-profile-trace", default=None, metavar="PATH",
                    help="with --soak: run the soak under a "
                         "DispatchProfiler and write a Chrome trace with "
                         "per-kernel spans + streamed-bytes counters "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    # The tp rows simulate a 4-way mesh on the host; the flag must land
    # before the first jax import in this process.
    if "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=4")
    import jax
    requests = args.requests or (6 if args.fast else 12)
    max_new = args.max_new or (6 if args.fast else 16)
    iters = 1 if args.fast else 3

    engines = []
    for mode in MODES:
        m = bench_engine(args.arch, mode, slots=args.slots,
                         cache_len=args.cache_len, requests=requests,
                         max_new=max_new, page_size=args.page_size)
        engines.append(m)
        extra = (f"  prefix_hit {m['prefix_hit_rate']:.2f}"
                 if "prefix_hit_rate" in m else "")
        print(f"{mode:<15} {m['decode_steps']:>4} steps  "
              f"{m['decode_steps_per_s']:>8.2f} steps/s  "
              f"{m['tokens_per_s']:>8.2f} tok/s  "
              f"ttft {m['ttft_s_mean']*1e3:>7.1f} ms  "
              f"{m['prefill_traces']} prefill compiles{extra}")

    spec_k = 3
    m = bench_engine(args.arch, f"speculative/k{spec_k}", slots=args.slots,
                     cache_len=args.cache_len, requests=requests,
                     max_new=max_new, page_size=args.page_size,
                     speculate_k=spec_k)
    # the TROOP claim the row exists to gate: >1 emitted token per target
    # weight pass (1.0 would mean speculation bought nothing)
    assert m["tokens_per_target_pass"] > 1.0, (
        f"speculative engine emitted {m['tokens_per_target_pass']} tokens "
        f"per target pass (expected > 1 at same-arch 100% acceptance)")
    engines.append(m)
    print(f"{m['mode']:<15} {m['decode_steps']:>4} steps  "
          f"{m['tokens_per_s']:>8.2f} tok/s  "
          f"accept {m['acceptance_rate']:.2f}  "
          f"tok/pass {m['tokens_per_target_pass']:.2f}  "
          f"rollback {m['rollback_pages']} pages")

    for tp in (1, 2, 4):
        mode = f"chunked+prefix/tp{tp}"
        m = bench_engine(args.arch, mode, slots=args.slots,
                         cache_len=args.cache_len, requests=requests,
                         max_new=max_new, page_size=args.page_size, tp=tp)
        engines.append(m)
        print(f"{mode:<15} {m['decode_steps']:>4} steps  "
              f"{m['tokens_per_s']:>8.2f} tok/s  "
              f"ttft p95 {m['ttft_s_p95']*1e3:>7.1f} ms  "
              f"kv/dev {m['kv_bytes_streamed_per_device']:>9,} B  "
              f"overlap {m['dispatch_overlap_fraction']:.2f}")

    m = bench_profiled_engine(args.arch, slots=args.slots,
                              cache_len=args.cache_len, requests=requests,
                              max_new=max_new, page_size=args.page_size)
    engines.append(m)
    pdec = next((p for p in m["profile"] if p["phase"] == "decode"), {})
    print(f"{m['mode']:<15} {m['decode_steps']:>4} steps  "
          f"{m['tokens_per_s']:>8.2f} tok/s  "
          f"decode {pdec.get('dispatches', 0)} dispatches  "
          f"{pdec.get('modeled_bytes', 0):,} B modeled")

    soak = soak_tp = soak_spec = None
    if args.soak:
        soak = bench_soak(args.arch, requests=args.soak, slots=args.slots,
                          cache_len=args.cache_len,
                          page_size=args.page_size,
                          profile_trace=args.soak_profile_trace)
        print(f"soak({args.soak:>3})      "
              f"ttft_steps p50/p95/p99 {soak['ttft_steps_p50']:.1f}/"
              f"{soak['ttft_steps_p95']:.1f}/{soak['ttft_steps_p99']:.1f}  "
              f"queue max {soak['queue_depth_max']}  "
              f"drained={soak['all_finished']}")
        soak_spec = bench_soak(args.arch, requests=args.soak,
                               slots=args.slots, cache_len=args.cache_len,
                               page_size=args.page_size,
                               speculate_k=spec_k)
        print(f"soak/spec({args.soak:>3}) "
              f"ttft_steps p50/p95 {soak_spec['ttft_steps_p50']:.1f}/"
              f"{soak_spec['ttft_steps_p95']:.1f}  "
              f"accept {soak_spec['acceptance_rate']:.2f}  "
              f"tok/pass {soak_spec['tokens_per_target_pass']:.2f}  "
              f"drained={soak_spec['all_finished']}")
        if args.soak_tp > 1:
            soak_tp = bench_soak(args.arch, requests=args.soak,
                                 slots=args.slots, cache_len=args.cache_len,
                                 page_size=args.page_size, tp=args.soak_tp)
            print(f"soak/tp{args.soak_tp}({args.soak:>3})  "
                  f"ttft_steps p50/p95 {soak_tp['ttft_steps_p50']:.1f}/"
                  f"{soak_tp['ttft_steps_p95']:.1f}  "
                  f"overlap {soak_tp.get('dispatch_overlap_fraction', 0):.2f}"
                  f"  drained={soak_tp['all_finished']}")

    kernels = bench_decode_kernels(slots=args.slots, cache_len=args.cache_len,
                                   page_size=args.page_size, iters=iters)
    for r in kernels:
        print(f"{r['kernel']:<24} {r['measured_us']:>10.1f} us  "
              f"roof {r['roofline_us']:>8.3f} us  "
              f"frac {r['fraction_of_roofline']:.3e}")

    payload = {
        "backend": jax.default_backend(),
        "interpret_mode": True,
        "engines": engines,
        "decode_kernels": kernels,
    }
    if soak is not None:
        payload["soak"] = soak
    if soak_tp is not None:
        payload["soak_tp"] = soak_tp
    if soak_spec is not None:
        payload["soak_spec"] = soak_spec
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
