"""Failure injection for fault-tolerance tests (simulated preemptions)."""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Set


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raises SimulatedFailure at the configured steps (once each).

    Configure via ``fail_at`` or env REPRO_FAIL_AT="7,23".
    """
    fail_at: Set[int] = field(default_factory=set)
    fired: Set[int] = field(default_factory=set)

    def __post_init__(self):
        env = os.environ.get("REPRO_FAIL_AT", "")
        if env:
            self.fail_at |= {int(x) for x in env.split(",") if x}

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")
