"""Production training launcher: ``python -m repro.launch.train --arch <id>``.

On a real pod this process runs per host with jax.distributed initialized by
the scheduler; on this CPU container use ``--smoke`` (reduced config, host
mesh) to exercise the identical code path end-to-end.
"""
import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.launch import sharding as SH
from repro.launch.mesh import data_shards, make_host_mesh, make_production_mesh
from repro.models import RuntimeConfig, build_model
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the host mesh")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
        mesh = make_host_mesh()
        rt = RuntimeConfig(remat="none", moe_groups=data_shards(mesh))
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rt = RuntimeConfig(remat="dots", moe_groups=data_shards(mesh))

    model = build_model(cfg, rt)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch,
                          frontend_tokens=cfg.frontend_tokens,
                          frontend_dim=cfg.d_model,
                          enc_frames=cfg.cross_attention_len
                          if cfg.encoder_decoder else 0)
    trainer = Trainer(model, OptConfig(decay_steps=args.steps), data_cfg,
                      TrainerConfig(total_steps=args.steps,
                                    ckpt_dir=args.ckpt_dir))
    _, _, hist = trainer.run()
    print("final:", hist[-1] if hist else "no metrics")


if __name__ == "__main__":
    main()
