"""whisper-base — encoder-decoder, conv frontend STUB.

[arXiv:2212.04356; unverified]  6L(enc)+6L(dec) d_model=512 8H d_ff=2048
vocab=51865.  ``input_specs()`` provides precomputed mel-frame embeddings
(the conv1d frontend is a stub per the assignment).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    pos_emb="learned",
    max_position_embeddings=448 * 128,   # scaled so assigned shapes fit
    encoder_decoder=True,
    num_encoder_layers=6,
    cross_attention_len=1500,
    frontend="audio",
    tie_embeddings=True,
)
