"""End-to-end training driver: ~100M-class model, fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --dim 512

Full substrate: deterministic sharded data, async checkpoints, watchdog,
failure injection (--fail-at), restart-and-resume.  At the default reduced
size this runs on CPU; on a real pod the same driver runs the full configs
via ``repro.launch.train``.
"""
import argparse

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.ft import FailureInjector
from repro.models import RuntimeConfig, build_model
from repro.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dim", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch),
                  num_layers=args.layers, d_model=args.dim,
                  d_ff=4 * args.dim, vocab_size=8192,
                  num_heads=args.dim // 64, num_kv_heads=args.dim // 64,
                  head_dim=64, max_position_embeddings=args.seq * 4)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    print(f"training {cfg.name}: params={cfg.param_count():,} "
          f"({cfg.param_count() / 1e6:.1f}M)")

    trainer = Trainer(
        model,
        OptConfig(lr=3e-4, warmup_steps=50, decay_steps=args.steps),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch),
        TrainerConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=10),
        failure_injector=FailureInjector(fail_at=set(args.fail_at)))
    _, _, hist = trainer.run()
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f}); straggler events: "
          f"{len(trainer.watchdog.events)}")


if __name__ == "__main__":
    main()
