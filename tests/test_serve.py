"""Continuous-batching serving engine: end-to-end, paged-vs-dense cache
backends, bucketed-prefill compile bounds, lifecycle + sampling RNG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve import EngineConfig
from repro.serve.kvcache import BlockAllocator, PagedBackend, bucket_length
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step, sample_keys


def setup():
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def make_engine(model, params, backend="dense", **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("cache_len", 32)
    name = backend if isinstance(backend, str) else backend.name
    return ServingEngine(
        model, prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params,
        backend=backend, config=EngineConfig(backend=name, **kw))


def test_engine_serves_batched_requests():
    cfg, model, params = setup()
    eng = ServingEngine(
        model, config=EngineConfig(slots=4, cache_len=32),
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + i) % 63 + 1,
                    max_new_tokens=6) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)


def test_engine_output_matches_sequential_decode():
    """Greedy outputs under continuous batching == single-request decode."""
    cfg, model, params = setup()
    prompt = np.asarray([3, 14, 15, 9, 2, 6], np.int32)

    # oracle: full forward + greedy loop (no engine)
    toks = list(prompt)
    for _ in range(4):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    want = toks[len(prompt):]

    eng = ServingEngine(
        model, config=EngineConfig(slots=2, cache_len=32),
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    req = Request(rid=0, prompt=prompt, max_new_tokens=4)
    # a competing request exercises multi-slot interference
    other = Request(rid=1, prompt=np.asarray([7, 7, 7], np.int32),
                    max_new_tokens=4)
    eng.submit(req)
    eng.submit(other)
    eng.run_until_drained()
    assert req.out == want


def test_slots_are_reused():
    cfg, model, params = setup()
    eng = ServingEngine(
        model, config=EngineConfig(slots=1, cache_len=24),
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.asarray([1 + i, 2, 3], np.int32),
                           max_new_tokens=3))
    eng.run_until_drained()
    assert eng.steps <= 3 * 3 + 3


def test_recurrent_arch_exact_prefill_matches_oracle():
    """Recurrent mixers (rwkv/mamba) must prefill at EXACT prompt length:
    right-padding to a bucket scans the state through pad tokens and hands
    decode a polluted state (attention masks pads; a scan cannot)."""
    cfg = reduced(get_config("rwkv6-3b"))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    prompt = np.asarray([3, 14, 15, 9, 2, 6], np.int32)   # not a pow2 bucket

    toks = list(prompt)
    for _ in range(3):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    want = toks[len(prompt):]

    eng = make_engine(model, params, slots=2)
    assert eng._exact_prefill
    req = Request(rid=0, prompt=prompt, max_new_tokens=3)
    other = Request(rid=1, prompt=np.asarray([7, 7, 7], np.int32),
                    max_new_tokens=3)
    eng.submit(req)
    eng.submit(other)
    finished = eng.run_until_drained()
    assert len(finished) == 2
    assert req.out == want


def test_encdec_serving_with_frontend_stub():
    """Whisper-style serving: frontend stub supplied via prefill_extras."""
    from repro.configs import get_config, reduced
    cfg = reduced(get_config("whisper-base"))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    extras = lambda req: {"frontend": 0.1 * jnp.ones(
        (1, cfg.cross_attention_len, cfg.d_model), jnp.bfloat16)}
    eng = ServingEngine(
        model, config=EngineConfig(slots=2, cache_len=32),
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params,
        prefill_extras=extras)
    reqs = [Request(rid=i, prompt=np.arange(1, 4 + i), max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done and len(r.out) == 4 for r in reqs)


def test_paged_matches_dense_greedy():
    """Token-identical greedy outputs under the paged and dense backends."""
    cfg, model, params = setup()
    outs = {}
    for backend in ("dense", "paged"):
        eng = make_engine(model, params, backend=backend, min_bucket=4)
        reqs = [Request(rid=i, prompt=np.arange(1, 4 + 2 * i) % 63 + 1,
                        max_new_tokens=6) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        finished = eng.run_until_drained()
        assert len(finished) == len(reqs) and all(r.done for r in reqs)
        outs[backend] = {r.rid: r.out for r in reqs}
    assert outs["paged"] == outs["dense"]


def test_bucketed_prefill_compiles_once_per_bucket():
    """6 distinct prompt lengths -> at most 3 prefill compiles (buckets)."""
    cfg, model, params = setup()
    lengths = [3, 4, 6, 8, 11, 15]          # buckets(min=4): 4, 8, 16
    assert len({bucket_length(n, 4) for n in lengths}) == 3
    eng = make_engine(model, params, backend="paged", min_bucket=4)
    for i, n in enumerate(lengths):
        eng.submit(Request(rid=i, prompt=np.arange(1, n + 1) % 63 + 1,
                           max_new_tokens=4))
    finished = eng.run_until_drained()
    assert len(finished) == len(lengths)
    assert eng.prefill_traces <= 3
    # re-serving the same length mix compiles nothing new
    traces = eng.prefill_traces
    for i, n in enumerate(lengths):
        eng.submit(Request(rid=10 + i, prompt=np.arange(2, n + 2) % 63 + 1,
                           max_new_tokens=4))
    eng.run_until_drained()
    assert eng.prefill_traces == traces


def test_run_until_drained_returns_finished_and_bounds_steps():
    cfg, model, params = setup()
    eng = make_engine(model, params)
    reqs = [Request(rid=i, prompt=np.asarray([5, 6, 7], np.int32),
                    max_new_tokens=8) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained(max_steps=2)
    assert eng.steps == 2 and finished == []       # bound respected exactly
    finished = eng.run_until_drained()
    assert sorted(r.rid for r in finished) == [0, 1]
    assert all(r.done and r.finish_step >= r.admit_step >= 0
               for r in finished)


def test_paged_admission_defers_when_pool_exhausted():
    """A pool sized for ~1 request forces serialized admission, no OOM."""
    cfg, model, params = setup()
    backend = PagedBackend(page_size=16, num_pages=3)   # 2 usable pages
    eng = make_engine(model, params, backend=backend, slots=3)
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + i) % 63 + 1,
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == 3 and all(r.done for r in reqs)
    assert backend.allocator.num_free == 2              # all pages returned


def test_paged_impossible_request_raises_at_submit():
    """A request that can NEVER fit the pool raises at submit — before
    anything is queued, popped, or reserved (backpressure != drop)."""
    cfg, model, params = setup()
    backend = PagedBackend(page_size=16, num_pages=2)   # 1 usable page
    eng = make_engine(model, params, backend=backend)
    with pytest.raises(ValueError, match="cache rows"):
        eng.submit(Request(rid=9, prompt=np.arange(1, 40) % 63 + 1))
    assert not eng.queue                                # prompt > cache_len
    eng.submit(Request(rid=0, prompt=np.asarray([1, 2], np.int32),
                       max_new_tokens=4))               # fits: 1 page
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(Request(rid=1, prompt=np.arange(1, 10) % 63 + 1,
                           max_new_tokens=16))          # needs 2 pages
    assert len(eng.queue) == 1                          # nothing stranded
    finished = eng.run_until_drained()
    assert [r.rid for r in finished] == [0]
    assert backend.allocator.num_free == 1              # no page leak


def test_splice_axis_resolution_with_ambiguous_dims():
    """cache_len == slots: the KV leaf is (B, S, ...) with S == slots, so a
    shape heuristic cannot tell batch from sequence — the engine derives
    each leaf's slot axis structurally (kvcache.slot_axes) and both
    backends must still agree token for token."""
    cfg, model, params = setup()
    outs = {}
    for backend in ("dense", "paged"):
        eng = make_engine(model, params, backend=backend,
                          slots=8, cache_len=8, min_bucket=4)
        reqs = [Request(rid=i, prompt=np.asarray([3 + i, 14, 15], np.int32),
                        max_new_tokens=3) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        assert len(eng.run_until_drained()) == 3
        outs[backend] = {r.rid: r.out for r in reqs}
    assert outs["paged"] == outs["dense"]


def test_paged_kernel_decode_matches_jnp_path():
    """RuntimeConfig(paged_kernel_decode=True) routes decode attention
    through the tuned Pallas paged kernel; logits match the jnp gather
    path on the same paged caches."""
    cfg, model, params = setup()
    kmodel = build_model(cfg, RuntimeConfig(remat="none",
                                            paged_kernel_decode=True))
    eng = make_engine(model, params, backend="paged", slots=2)
    eng.submit(Request(rid=0, prompt=np.asarray([3, 14, 15, 9], np.int32),
                       max_new_tokens=2))
    eng.step()                                   # admit + one decode step
    batch = {"tokens": jnp.asarray(eng.last_tok[:, None]),
             "pos": jnp.asarray(eng.pos)}
    batch.update(eng.backend.batch_extras())
    logits_jnp, _ = model.decode_step(params, batch, eng.caches)
    logits_ker, _ = kmodel.decode_step(params, batch, eng.caches)
    np.testing.assert_allclose(
        np.asarray(logits_ker[0], np.float32),
        np.asarray(logits_jnp[0], np.float32), rtol=3e-2, atol=3e-2)


def test_block_allocator():
    a = BlockAllocator(6)                               # pages 1..5 usable
    got = a.alloc(5)
    assert sorted(got) == [1, 2, 3, 4, 5] and a.alloc(1) is None
    a.free(got[:2])
    assert a.num_free == 2 and a.alloc(3) is None
    assert len(a.alloc(2)) == 2


def test_sample_keys_unique_per_slot_and_step():
    """Per-slot sampling RNG: no two (slot, pos) rows share a key (the seed
    engine folded only pos[0], correlating samples across slots)."""
    pos = jnp.asarray([7, 7, 9, 9], jnp.int32)
    keys = np.asarray(sample_keys(pos, 4))
    assert len({tuple(k) for k in keys}) == 4           # same pos, same step
    keys2 = np.asarray(sample_keys(pos + 1, 4))
    assert not any(tuple(a) == tuple(b) for a in keys for b in keys2)
    # a new request reusing the slot (fresh nonce) must not replay keys
    n1 = np.asarray(sample_keys(pos, 4, nonce=jnp.full((4,), 1, jnp.int32)))
    n2 = np.asarray(sample_keys(pos, 4, nonce=jnp.full((4,), 2, jnp.int32)))
    assert not any(tuple(a) == tuple(b) for a in n1 for b in n2)


def test_temperature_sampling_varies_across_identical_slots():
    cfg, model, params = setup()
    step = make_serve_step(model, temperature=1.0)
    caches = model.init_caches(8, 32)
    batch = {"tokens": jnp.full((8, 1), 5, jnp.int32),
             "pos": jnp.full((8,), 3, jnp.int32)}
    tok, _ = jax.jit(step)(params, batch, caches)
    # identical rows + identical caches: only the per-slot fold can
    # decorrelate them (vocab 128, 8 slots -> collision-only equality)
    assert len(set(np.asarray(tok)[:, 0].tolist())) > 1


def test_serving_with_int8_kv_cache():
    """§Perf A4 in the engine: int8 caches serve correctly end-to-end."""
    cfg, model_bf16, params = setup()
    model = build_model(cfg, RuntimeConfig(remat="none", cache_dtype="int8"))
    eng = ServingEngine(
        model, config=EngineConfig(slots=2, cache_len=32),
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params)
    req = Request(rid=0, prompt=np.asarray([3, 14, 15, 9], np.int32),
                  max_new_tokens=5)
    eng.submit(req)
    eng.run_until_drained()
    assert req.done and len(req.out) == 5
