"""Roofline-guided config search: enumerate -> analytic prune -> measure.

The paper's tuning loop, mechanized: a kernel is *done* when its runtime
equals the roofline bound, so candidates are scored as fraction-of-roofline

    fraction = t_roofline / t_measured,
    t_roofline = max(bytes / BW, flops / PEAK)

and the search never times a config the analytic model already ranks as
dominated.  The analytic predictor composes three terms:

  * stream/decoupling efficiency — for the paper's own kernels (dotp, axpy,
    gemv) the Spatz cycle model (``core.perfmodel``) simulates the mapped
    micro-architecture config; other kernels use the closed-form Fig. 5
    shape (single interface ~55%, decoupled ~96%, unscrambled conflicts cap
    one axis at half throughput);
  * per-grid-step work amortization (unroll x block volume vs fixed
    per-step overhead — §IV-F);
  * hardware-layout alignment of the tile shape (§IV-D/E granules).

Pruning keeps the top-``keep`` predicted candidates, so the
predicted-best config is *never* discarded (tested).
"""
from __future__ import annotations

import functools
import itertools
import os
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import perfmodel
from repro.core.roofline import HBM_BW, PEAK_FLOPS
from repro.core.troop import TroopConfig
from repro.tune import cache as tcache
from repro.tune import registry

# kernels with a micro-program in the Spatz cycle model
_SPATZ_KERNELS = ("dotp", "axpy", "gemv")


def roofline_bw() -> float:
    """HBM roofline bytes/s; ``REPRO_TUNE_BW`` overrides (e.g. a measured
    CPU STREAM number when tuning in interpret mode)."""
    return float(os.environ.get("REPRO_TUNE_BW", HBM_BW))


def roofline_time(spec: registry.KernelSpec, args: Sequence[Any]) -> float:
    return max(float(spec.bytes(*args)) / roofline_bw(),
               float(spec.flops(*args)) / PEAK_FLOPS)


# --------------------------------------------------------------------------
# candidate enumeration
# --------------------------------------------------------------------------
def enumerate_space(spec: registry.KernelSpec,
                    base: Optional[TroopConfig] = None) -> List[TroopConfig]:
    base = base if base is not None else spec.default
    knobs = list(spec.space.items())
    out: List[TroopConfig] = []
    seen = set()
    for combo in itertools.product(*(vals for _, vals in knobs)):
        cfg = replace(base, **dict(zip((k for k, _ in knobs), combo)))
        try:
            cfg.validate()
        except AssertionError:
            continue
        if cfg not in seen:
            seen.add(cfg)
            out.append(cfg)
    return out


# --------------------------------------------------------------------------
# analytic prediction
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=128)
def _spatz_util(kernel: str, streams: int, unroll: int,
                scrambled: bool) -> float:
    """FPU utilization of the mapped Spatz config (cycle-level sim)."""
    troop = streams == 2
    # unrolling/software-pipelining hides the scalar-core strip overhead
    sw = 0 if (troop and unroll >= 2) else max(14 // (streams * unroll), 0)
    cfg = perfmodel.SpatzConfig(
        f"tune_{kernel}_{streams}_{unroll}_{int(scrambled)}",
        mem_beats_per_cycle=2 if troop else 1,
        decoupled=troop, completion_chaining=troop, dynamic_priority=troop,
        scrambling=scrambled, log2_reduction=troop,
        shadow_depth=3, sw_strip_overhead=sw)
    return perfmodel.utilization(kernel, cfg, vl=2048).fpu_util


def _stream_term(spec: registry.KernelSpec, cfg: TroopConfig) -> float:
    if spec.name in _SPATZ_KERNELS:
        return _spatz_util(spec.name, cfg.streams, cfg.unroll,
                           cfg.scrambled_layout)
    # closed-form Fig. 5 shape for kernels without a Spatz micro-program
    if cfg.streams == 2:
        return 0.96 if cfg.scrambled_layout else 0.72
    return 0.55


def _amortization_term(cfg: TroopConfig) -> float:
    # fixed per-grid-step cost vs per-step work volume (§IV-F unrolling)
    per_step = float(cfg.block_n) * float(cfg.block_k) * float(cfg.unroll)
    return per_step / (per_step + 8192.0)


def _alignment_term(cfg: TroopConfig, args: Sequence[Any]) -> float:
    from repro.core.troop import sublane
    dtype = None
    dims: List[int] = []
    for a in args:
        if getattr(a, "shape", None) is not None and len(a.shape):
            if dtype is None:
                dtype = a.dtype
            dims.append(int(a.shape[-1]))
    score = 1.0
    if cfg.block_n % 128 or cfg.block_k % 128:
        score *= 0.9                  # off-lane tile edge (§IV-D)
    if dtype is not None and cfg.block_n % sublane(dtype):
        score *= 0.95
    # blocks larger than any streamed extent get clamped inside the kernel:
    # harmless but no extra amortization — mild penalty keeps ranks stable
    if dims and cfg.block_k > max(dims) * 4:
        score *= 0.98
    return score


def predict_fraction(spec: registry.KernelSpec, cfg: TroopConfig,
                     *args) -> float:
    """Analytic fraction-of-roofline for (kernel, config, shapes)."""
    return (_stream_term(spec, cfg) * _amortization_term(cfg)
            * _alignment_term(cfg, args))


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------
@dataclass
class Candidate:
    cfg: TroopConfig
    predicted: float
    measured_s: Optional[float] = None
    achieved: Optional[float] = None      # fraction-of-roofline, measured
    error: Optional[str] = None


def prune(candidates: List[Candidate], keep: int) -> List[Candidate]:
    """Top-``keep`` by analytic prediction; the predicted-best candidate is
    first and therefore always survives."""
    ranked = sorted(candidates, key=lambda c: -c.predicted)
    return ranked[:max(int(keep), 1)]


def _block(out):
    import jax
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)


def measure(spec: registry.KernelSpec, cfg: TroopConfig,
            args: Sequence[Any], kwargs: Optional[Dict[str, Any]] = None,
            iters: int = 2) -> float:
    """Best-of-``iters`` wall time of the raw kernel (post-warmup)."""
    kwargs = kwargs or {}
    _block(spec.fn(*args, cfg=cfg, **kwargs))      # compile + warm
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        _block(spec.fn(*args, cfg=cfg, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best


# --------------------------------------------------------------------------
# the tune entry point
# --------------------------------------------------------------------------
@dataclass
class TuneResult:
    name: str
    key: str
    best: TroopConfig
    fraction: float                    # measured fraction-of-roofline
    predicted: float
    measured_s: Optional[float]
    roofline_s: float
    from_cache: bool = False
    timings_run: int = 0               # measure() invocations this call
    candidates: List[Candidate] = field(default_factory=list)

    def as_entry(self) -> Dict[str, Any]:
        return {
            "kernel": self.name,
            "config": tcache.config_to_dict(self.best),
            "fraction_of_roofline": self.fraction,
            "predicted_fraction": self.predicted,
            "measured_s": self.measured_s,
            "roofline_s": self.roofline_s,
            "tuned_at": time.time(),
        }


def tune(name: str, *args, kernel_kwargs: Optional[Dict[str, Any]] = None,
         keep: int = 4, iters: int = 2,
         cache: Optional[tcache.TuneCache] = None,
         force: bool = False, save: bool = True) -> TuneResult:
    """Tune one (kernel, shape, dtype) point end to end.

    Cached results short-circuit (no re-timing) unless ``force=True``.
    ``keep`` survivors of the analytic prune are timed; the winner by
    measured fraction-of-roofline is persisted.
    """
    spec = registry.get(name)
    c = cache if cache is not None else tcache.default_cache()
    key = spec.key(*args, kwargs=kernel_kwargs)

    if not force:
        entry = c.get(key)
        if entry is not None and "config" in entry:
            return TuneResult(
                name=name, key=key,
                best=tcache.config_from_dict(entry["config"]),
                fraction=entry.get("fraction_of_roofline", 0.0),
                predicted=entry.get("predicted_fraction", 0.0),
                measured_s=entry.get("measured_s"),
                roofline_s=entry.get("roofline_s",
                                     roofline_time(spec, args)),
                from_cache=True, timings_run=0)

    roof = roofline_time(spec, args)
    cands = [Candidate(cfg, predict_fraction(spec, cfg, *args))
             for cfg in enumerate_space(spec)]
    survivors = prune(cands, keep)

    timings = 0
    for cand in survivors:
        try:
            cand.measured_s = measure(spec, cand.cfg, args, kernel_kwargs,
                                      iters=iters)
            cand.achieved = roof / max(cand.measured_s, 1e-12)
            timings += 1
        except Exception as e:              # infeasible (shape, space) combo
            cand.error = f"{type(e).__name__}: {e}"

    ok = [cand for cand in survivors if cand.measured_s is not None]
    if ok:
        winner = max(ok, key=lambda cand: cand.achieved)
    else:
        winner = survivors[0]               # all failed: keep predicted-best
    res = TuneResult(
        name=name, key=key, best=winner.cfg,
        fraction=winner.achieved or 0.0, predicted=winner.predicted,
        measured_s=winner.measured_s, roofline_s=roof,
        from_cache=False, timings_run=timings, candidates=cands)
    if ok:
        c.put(key, res.as_entry())
        if save:
            c.save()
    return res
