"""DOTP kernel — s = x . y (the paper's 2:1 bandwidth-to-compute kernel).

Two operands per FMA: at a 1:1 memory ratio the FPU tops out at 50% (paper
§II); the kernel therefore streams *four* half-streams (two per operand) when
``streams=2``.  The scalar partial accumulates in SMEM scratch across grid
steps (shadow-buffer intent) and each tile reduces as a tree on the VPU (G).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel


def _example(small: bool = True):
    key = jax.random.PRNGKey(0)
    n = 4096 if small else 1 << 20
    x = jax.random.normal(key, (n,), jnp.bfloat16)
    y = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.bfloat16)
    return (x, y), {}


def _kernel_1s(x_ref, y_ref, o_ref, acc):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        acc[0, 0] = 0.0

    acc[0, 0] += jnp.sum(x_ref[...].astype(jnp.float32)
                         * y_ref[...].astype(jnp.float32))

    @pl.when(j == pl.num_programs(0) - 1)
    def _():
        o_ref[0, 0] = acc[0, 0]


def _kernel_2s(x0, x1, y0, y1, o_ref, acc):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        acc[0, 0] = 0.0

    p0 = jnp.sum(x0[...].astype(jnp.float32) * y0[...].astype(jnp.float32))
    p1 = jnp.sum(x1[...].astype(jnp.float32) * y1[...].astype(jnp.float32))
    acc[0, 0] += p0 + p1

    @pl.when(j == pl.num_programs(0) - 1)
    def _():
        o_ref[0, 0] = acc[0, 0]


@troop_kernel(
    "dotp",
    flops=lambda x, y: 2.0 * x.shape[0],
    bytes=lambda x, y: x.shape[0] * (itemsize(x) + itemsize(y)) + 4,
    streamed=lambda x, y: [x, y, jax.ShapeDtypeStruct((1,), jnp.float32)],
    space={"streams": (1, 2), "unroll": (1, 2, 4),
           "block_k": (256, 512, 1024)},
    ref="dotp", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def dotp(x, y, cfg: TroopConfig = TroopConfig()):
    """x, y (K,) -> scalar fp32."""
    K = x.shape[0]
    lanes = 128
    bk = min(cfg.block_k * cfg.unroll, K // (cfg.streams * lanes) * lanes)
    bk = max(bk // lanes * lanes, lanes)
    x2, y2 = x.reshape(-1, lanes), y.reshape(-1, lanes)
    rows = x2.shape[0]
    br = max(bk // lanes, 1)

    if cfg.streams == 1:
        while rows % br:
            br //= 2
        out = pl.pallas_call(
            _kernel_1s,
            grid=(rows // br,),
            in_specs=[pl.BlockSpec((br, lanes), lambda j: (j, 0)),
                      pl.BlockSpec((br, lanes), lambda j: (j, 0))],
            out_specs=pl.BlockSpec((1, 1), lambda j: (0, 0),
                                   memory_space=pltpu.SMEM),
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
            scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
            interpret=cfg.interpret,
        )(x2, y2)
        return out[0, 0]

    half = rows // 2
    while half % br:
        br //= 2
    steps = half // br
    out = pl.pallas_call(
        _kernel_2s,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((br, lanes), lambda j: (j, 0)),
            pl.BlockSpec((br, lanes), lambda j, o=steps: (j + o, 0)),
            pl.BlockSpec((br, lanes), lambda j: (j, 0)),
            pl.BlockSpec((br, lanes), lambda j, o=steps: (j + o, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda j: (0, 0),
                               memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=cfg.interpret,
    )(x2, x2, y2, y2)
    return out[0, 0]
