"""Roofline report: summarize the dry-run's per-cell terms (EXPERIMENTS.md
§Roofline source).  Reads experiments/dryrun/*.json if present."""
from __future__ import annotations

import glob
import json
import os


def run(csv=print, dryrun_dir: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*__single.json")))
    if not files:
        csv("roofline/none,0,no dryrun records found")
        return
    for f in files:
        r = json.load(open(f))
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        csv(f"roofline/{r['arch']}/{r['shape']},"
            f"{rf['bound_step_s'] if 'bound_step_s' in rf else max(rf['t_compute_s'], rf['t_memory_s'], rf['t_collective_s']):.4f},"
            f"dom={rf['dominant']} tc={rf['t_compute_s']:.3f} "
            f"tm={rf['t_memory_s']:.3f} tx={rf['t_collective_s']:.3f} "
            f"useful={rf['useful_flops_ratio']:.3f}")
    multi = len(glob.glob(os.path.join(dryrun_dir, "*__multi.json")))
    csv(f"roofline/multi_pod_cells,{multi},compiled OK on (2,16,16)")


if __name__ == "__main__":
    run()
