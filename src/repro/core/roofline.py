"""Roofline-term derivation from compiled XLA artifacts (TPU v5e model).

Mirrors the paper's methodology at cluster scale: the paper measures FPU
utilization against the L1-memory roofline; here the three terms are

    compute    = HLO_FLOPs            / (chips * 197e12  bf16 FLOP/s)
    memory     = HLO_bytes_accessed   / (chips * 819e9   B/s HBM)
    collective = collective_link_bytes/ (chips * 50e9    B/s ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the post-SPMD HLO text (per-shard shapes), weighted per op kind
by the bytes a device must move on its ICI links under a ring schedule:

    all-gather:        out - in   (received bytes)
    all-reduce:        2 * in     (reduce-scatter + all-gather)
    reduce-scatter:    in
    all-to-all:        in
    collective-permute: in
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# result may be a tuple: "%x = (f32[8,128], f32[8,128]) all-reduce("
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def cost_analysis(compiled) -> dict:
    """Version-tolerant ``compiled.cost_analysis()``: some jaxlib versions
    return a one-element list of dicts, newer ones the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def link_bytes(self) -> float:
        """ICI bytes a device moves (ring-schedule weights)."""
        w = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}
        return sum(w[k] * v for k, v in self.bytes_by_kind.items())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_CONVERT_RE = re.compile(
    r"= ([a-z0-9]+)\[([0-9,]*)\][^=]*? (?:convert|fusion\([^)]*\), kind=kLoop,"
    r" calls=%?wrapped_convert)")
_CONVERT_NAME_RE = re.compile(
    r"%(?:wrapped_)?convert[\w.]* = ([a-z0-9]+)\[([0-9,]*)\]")


def convert_bytes(hlo_text: str) -> int:
    """Bytes moved by dtype-convert ops.

    XLA:CPU materializes fp32 copies of bf16 dot operands (no native bf16);
    the TPU MXU/VPU converts in-flight.  The roofline's adjusted memory term
    subtracts these artifact bytes (in+out ~ 1.5x the output size).
    """
    total = 0
    for m in _CONVERT_NAME_RE.finditer(hlo_text):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out_b = n * _DTYPE_BYTES[dt]
        if out_b >= 1 << 20:            # only large tensors
            total += int(out_b * 1.5)
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":       # async pair: count the -start only
            continue
        b = _shape_bytes(shape_txt)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


@dataclass
class RooflineTerms:
    """All inputs are PER-CHIP quantities: the compiled module analyzed by
    ``cost_analysis`` is the per-device SPMD program (measured — a 256-way
    sharded matmul reports 1/256 of the global FLOPs)."""
    flops: float                   # per-chip HLO FLOPs
    bytes_accessed: float          # per-chip HLO bytes
    collective_link_bytes: float   # per-chip ICI bytes (ring-weighted)
    chips: int
    model_flops: float = 0.0       # GLOBAL analytical 6ND / 2ND

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_link_bytes / ICI_BW

    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def bound_step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def useful_flops_ratio(self) -> float:
        tot = self.flops * self.chips
        return self.model_flops / tot if tot else 0.0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes_accessed": self.bytes_accessed,
            "collective_link_bytes": self.collective_link_bytes,
            "chips": self.chips, "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant(),
            "useful_flops_ratio": self.useful_flops_ratio(),
        }


def model_flops_for(cfg, shape) -> float:
    """Analytical MODEL_FLOPS: 6·N·D (train) / 2·N_active·D (inference)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch   # decode: one token
