"""``quantize_params`` — walk a model's params pytree and quantize the
matmul weights by path policy.

Policy (the ISSUE's "MLP/attention projections yes; embeddings/norms no"):

  * quantize: ``w`` leaves of the dense projections the models apply
    through ``modules.apply_dense`` — attention/MLA/rwkv projections
    (wq/wk/wv/wo/wg/wr/wdkv), MLP halves (wi_gate/wi_up), the lm_head;
  * keep raw: embeddings (the ``table`` doubles as the tied unembed),
    positional tables, norms, biases, routers, MoE *expert* stacks (the
    MoE dispatch einsums read ``p[...]["w"]`` directly — a shared-expert
    MLP nested under an expert block still quantizes, it goes through
    ``apply_dense``), MLA up-projections wuk/wuv (the absorbed decode path
    reads the raw array to build the latent-space einsums).

Grouping is along the *contraction* axis (``axis=-2`` of an (in, out)
weight — stacked layer groups (L, in, out) slice through ``lax.scan``
untouched because the axis is stored negative), with ``group_size`` a
multiple of the int8 layout granule so scale blocks tile exactly with the
mechanism-D blocks the qgemv kernels fetch (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.tensor import (QuantizedTensor, granule, quantize,
                                quantize_mx)

# dense projections that every model applies via modules.apply_dense
QUANTIZE_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wg", "wr", "wdkv",
    "wi_gate", "wi_up", "lm_head",
})
# raw-array access in model code: never quantize these
EXCLUDE_KEYS = frozenset({"wuk", "wuv", "embed", "pos_table", "router"})


def _path_keys(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def default_policy(keys: Tuple[str, ...], leaf) -> bool:
    """True iff the leaf at dict-path ``keys`` is a quantizable weight."""
    if len(keys) < 2 or keys[-1] != "w":
        return False
    if any(k in EXCLUDE_KEYS for k in keys):
        return False
    if keys[-2] not in QUANTIZE_KEYS:
        return False
    arr = getattr(leaf, "value", leaf)          # boxed Param or raw array
    if getattr(arr, "ndim", 0) < 2:
        return False
    return jnp.issubdtype(jnp.asarray(arr).dtype, jnp.floating)


def _moe_expert_prefixes(paths) -> set:
    """Dict-prefixes of MoE blocks: any dict that also holds a ``router``
    is an expert container — its direct wi_*/wo members are the stacked
    expert weights read raw by the dispatch einsums."""
    out = set()
    for keys in paths:
        if len(keys) >= 2 and keys[-2] == "router":
            out.add(keys[:-2])
    return out


def quantize_params(params, *, bits: int = 8, group_size: int = 128,
                    fmt: str = "int", policy: Optional[Callable] = None,
                    scale_dtype=jnp.float32, tp: int = 1):
    """Quantize the matmul weights of an (unboxed) params pytree.

    Returns the same tree with policy-selected ``w`` leaves replaced by
    ``QuantizedTensor``s (``modules.apply_dense`` dequantizes on the fly;
    the decode GEMVs have fused-dequant Pallas kernels in
    ``repro.quant.kernels``).  ``bits``: 8 or 4 (int4 packs two values per
    byte).  ``group_size`` groups the contraction axis and must be a
    multiple of the int8 layout granule (mechanism-D alignment).

    ``fmt``: ``"int"`` (absmax int8/int4, the default), ``"mx4"`` or
    ``"fp8"`` (MX microscaling — per-block E8M0 shared exponents, block
    size fixed at the layout granule; ``bits``/``group_size`` are ignored).
    Under MX the path policy FLIPS for MoE expert stacks: the stacked
    expert weights quantize too (the grouped expert kernel dispatches them
    per router selection — DESIGN.md §11) while routers/norms/embeds stay
    raw as ever.

    ``tp``: tensor-parallel degree the tree will serve under.  Row-parallel
    projections (``wo`` under overlap collectives) shard the contraction
    axis, so each shard must hold a whole number of scale groups — a group
    straddling the shard boundary would mix rows from two devices.  The
    alignment is checked here, at quantize time, per the sharding contract
    in ``repro.dist.tp``.
    """
    assert fmt in ("int", "mx4", "fp8"), f"fmt must be int|mx4|fp8: {fmt}"
    mx = fmt != "int"
    mx_block = granule()
    assert bits in (8, 4)
    assert group_size % granule() == 0, \
        f"group_size {group_size} not a multiple of the {granule()}-row " \
        f"int8 layout granule (mechanism D — see DESIGN.md §5)"
    if tp > 1:
        assert fmt != "mx4", \
            "mx4 packs fp4 row pairs that would straddle the " \
            "tensor-parallel shard boundary; use fmt='fp8' under tp > 1"
        assert mx or bits == 8, \
            "int4 packs row pairs that would straddle the tensor-parallel " \
            "shard boundary; use bits=8 under tp > 1"
    pol = policy or default_policy
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    moe = _moe_expert_prefixes([_path_keys(p) for p, _ in leaves])

    def visit(path, leaf):
        keys = _path_keys(path)
        if not mx and len(keys) >= 2 and keys[:-2] in moe:
            return leaf                          # stacked MoE expert weights
        if not pol(keys, leaf):
            return leaf
        if tp > 1 and keys[-2] == "wo":
            # row-parallel candidate: contraction axis K is sharded over tp
            # under overlap collectives — scale groups must tile each shard
            K = leaf.shape[-2]
            gs = mx_block if mx else group_size
            assert K % tp == 0 and (K // tp) % gs == 0, \
                f"'{'/'.join(keys)}' contraction extent {K} does not hold " \
                f"a whole number of {gs}-row scale groups per " \
                f"tp={tp} shard (groups must not straddle the shard " \
                f"boundary)"
        if mx:
            return quantize_mx(leaf, elem="fp4" if fmt == "mx4" else "fp8",
                               axis=-2)
        # int4 packs pairs along the contraction axis: odd extents stay int8
        b = bits if (bits == 8 or leaf.shape[-2] % 2 == 0) else 8
        return quantize(leaf, bits=b, group_size=group_size, axis=-2,
                        scale_dtype=scale_dtype)

    return jax.tree_util.tree_map_with_path(visit, params)


def quantized_stats(params) -> Dict[str, Any]:
    """Byte accounting of a (possibly) quantized tree: raw vs quantized
    leaf counts, total parameter bytes (the roofline numerator), and the
    fp32 bytes the quantized leaves replaced (the roofline *move*)."""
    import math
    n_q = n_raw = b_q = b_raw = b_was = 0

    def visit(leaf):
        nonlocal n_q, n_raw, b_q, b_raw, b_was
        if isinstance(leaf, QuantizedTensor):
            n_q += 1
            b_q += leaf.nbytes
            b_was += int(math.prod(leaf.shape)) * 4
        else:
            n_raw += 1
            b_raw += getattr(leaf, "size", 0) * \
                jnp.dtype(getattr(leaf, "dtype", jnp.float32)).itemsize
        return leaf

    jax.tree.map(visit, params,
                 is_leaf=lambda x: isinstance(x, QuantizedTensor))
    return {"quantized_leaves": n_q, "raw_leaves": n_raw,
            "quantized_bytes": int(b_q), "raw_bytes": int(b_raw),
            "quantized_fp32_bytes": int(b_was)}
