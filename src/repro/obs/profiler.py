"""Kernel-dispatch profiler — measured-vs-modeled roofline attribution.

``DispatchProfiler`` hooks the registry dispatch seam
(``repro.tune.registry.PROFILER``): every ``@troop_kernel`` wrapper call is
recorded — kernel name, arg signature, the resolved ``TroopConfig``, and
modeled flops/bytes from the spec's registered cost models — then invoked
with exactly the config the plain dispatch path would have used.  With no
profiler installed the wrapper pays a single module-attr check.

Phase contexts
--------------
The serving engine brackets its step submissions in ``profiler.phase``
(``admit`` / ``bucketed_prefill`` / ``chunk_prefill`` / ``decode`` /
``collective``, the last tagged ``@tpN`` under tensor parallelism).  All
engine steps are jitted, so registry dispatches only fire while a step
*traces*; the profiler therefore memoizes the dispatch list captured during
a phase's tracing occurrence as that phase's *program* (keyed by
``(phase, key)`` — e.g. one program per prefill bucket) and replays it into
the aggregates on every later occurrence of the same phase.  A program can
also be *seeded* from a modeled account (``seed_phase`` +
``obs.energy.decode_step_account``) — the dispatch audit below is what
makes that substitution sound.

Aggregation is per ``(phase, kernel, signature)``: dispatch counts, modeled
bytes/flops, modeled Spatz time (memory-roofline cycles + issue overhead at
1 GHz), and — against the per-phase measured wall — achieved bytes/s and
fraction-of-roofline vs the ``BW2X_TROOP`` bound.  Counts and modeled bytes
are deterministic (exact CI gates); wall-derived fractions are host
measurements (info band).  An attached ``Tracer`` receives per-kernel spans
on a ``kernels`` track plus cumulative ``streamed_bytes`` / ``dispatches``
counter tracks, so a profiled soak opens in Perfetto with kernel-level
attribution.

Dispatch audit
--------------
``audit_decode_step`` replays ONE engine decode step (B=1) under the
profiler with ``models.modules.kernel_routing`` active — every projection,
norm, unembed and MoE expert routes through the registry kernels — via
``jax.eval_shape`` (abstract, so nothing is compiled or executed) and
asserts the captured kernel multiset and summed modeled bytes exactly equal
``decode_step_account``'s enumeration.  That turns the modeled energy/SLO
rows from assumption into checked invariant: model-code drift that adds,
drops or reshapes a kernel fails the audit loudly.
"""
from __future__ import annotations

import contextlib
import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import perfmodel as PM
from repro.tune import registry as _reg
from repro.tune.registry import arg_signature

CLOCK_HZ = 1e9          # the Spatz cycle model is quoted at 1 GHz


@dataclass(frozen=True)
class DispatchRecord:
    """One registry-kernel dispatch (or one modeled call from a seeded
    program).  ``cfg`` is the resolved TroopConfig (None when seeded)."""
    kernel: str
    signature: str
    cfg: Any
    modeled_flops: float
    modeled_bytes: float
    phase: str = ""
    timed_s: float = 0.0


def modeled_time_s(bytes_: float, flops: float, launches: int,
                   spatz: PM.SpatzConfig = PM.BW2X_TROOP) -> float:
    """Spatz roofline time: max(memory, FLOP) beats + per-launch issue
    overhead, at 1 GHz — the same fold as ``obs.energy.EnergyModel``."""
    from repro.obs.energy import BEAT_BYTES, FLOPS_PER_BEAT
    mem_cycles = bytes_ / BEAT_BYTES / spatz.mem_beats_per_cycle
    cycles = max(mem_cycles, flops / FLOPS_PER_BEAT) \
        + launches * spatz.issue_overhead
    return cycles / CLOCK_HZ


def roofline_bytes_per_s(spatz: PM.SpatzConfig = PM.BW2X_TROOP) -> float:
    from repro.obs.energy import BEAT_BYTES
    return spatz.mem_beats_per_cycle * BEAT_BYTES * CLOCK_HZ


class DispatchProfiler:
    """Records registry-kernel dispatches grouped by engine phase.

    ``timed=True`` additionally blocks on every *concrete* dispatch
    (``jax.block_until_ready``) and records per-call wall time — opt-in,
    since it serializes the async pipeline; trace-time dispatches (tracer
    args) are never timed.
    """

    def __init__(self, *, tracer=None, timed: bool = False,
                 spatz: PM.SpatzConfig = PM.BW2X_TROOP):
        self.tracer = tracer
        self.timed = timed
        self.spatz = spatz
        self.records: List[DispatchRecord] = []     # raw trace-time log
        self._stack: List[Dict[str, Any]] = []      # open phase frames
        self._programs: Dict[Tuple[str, Any], List[DispatchRecord]] = {}
        self._pinned: set = set()                   # seeded (label, key)s
        self._agg: Dict[Tuple[str, str, str], Dict[str, float]] = {}
        self._phases: Dict[str, Dict[str, float]] = {}
        self._cum_bytes = 0.0
        self._cum_dispatches = 0

    # ------------------------------------------------------------ install
    def install(self) -> "DispatchProfiler":
        _reg.install_profiler(self)
        return self

    def uninstall(self) -> None:
        _reg.uninstall_profiler(self)

    def __enter__(self) -> "DispatchProfiler":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # ------------------------------------------------------------- record
    def record(self, spec, fn, args, kwargs):
        """Called by the registry dispatch wrapper: log the invocation,
        then invoke ``fn`` with exactly the config plain dispatch would
        have resolved (explicit ``TroopConfig`` wins; else the tuned
        cache / heuristic default)."""
        from repro.core.troop import TroopConfig
        explicit = kwargs.get("cfg") is not None or \
            any(isinstance(a, TroopConfig) for a in args)
        margs = tuple(a for a in args if not isinstance(a, TroopConfig))
        if explicit:
            cfg = kwargs["cfg"] if kwargs.get("cfg") is not None else \
                next(a for a in args if isinstance(a, TroopConfig))
            call = lambda: fn(*args, **kwargs)              # noqa: E731
        else:
            kw = dict(kwargs)
            kw.pop("cfg", None)
            from repro.tune.cache import get_tuned
            cfg = get_tuned(spec.name, *args, variant_kwargs=kw)
            call = lambda: fn(*args, cfg=cfg, **kw)         # noqa: E731

        timed_s = 0.0
        if self.timed and not self._abstract(margs):
            import jax
            t0 = time.perf_counter()
            out = call()
            jax.block_until_ready(out)
            timed_s = time.perf_counter() - t0
        else:
            out = call()

        rec = DispatchRecord(
            kernel=spec.name, signature=arg_signature(margs), cfg=cfg,
            modeled_flops=float(spec.flops(*margs)),
            modeled_bytes=float(spec.bytes(*margs)),
            phase=self._stack[-1]["label"] if self._stack else "",
            timed_s=timed_s)
        self.records.append(rec)
        if self._stack:
            self._stack[-1]["dispatches"].append(rec)
        else:
            self._aggregate("", [rec])      # unphased: aggregate directly
        return out

    @staticmethod
    def _abstract(args) -> bool:
        import jax
        return any(isinstance(a, jax.core.Tracer) for a in args)

    # ------------------------------------------------------------- phases
    @contextlib.contextmanager
    def phase(self, name: str, key: Any = None, devices: int = 1):
        """Bracket an engine step.  Dispatches fired inside (i.e. while
        the step traces) become the ``(name, key)`` program; every exit —
        traced or cache-hit — counts one occurrence, adds the measured
        wall, and replays the program into the aggregates."""
        label = name if devices <= 1 else f"{name}@tp{devices}"
        frame = {"label": label, "key": key, "dispatches": []}
        self._stack.append(frame)
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            if self._stack and self._stack[-1] is frame:
                self._stack.pop()
            else:                           # tolerate reset() mid-phase
                self._stack = [f for f in self._stack if f is not frame]
            wall = time.perf_counter() - t0
            self._close(label, key, frame["dispatches"], wall, t0)

    def _close(self, label, key, dispatches, wall, t0_abs):
        pk = (label, key)
        if dispatches and pk not in self._pinned:
            self._programs[pk] = list(dispatches)
        prog = self._programs.get(pk, [])
        ph = self._phase_row(label)
        ph["occurrences"] += 1
        ph["wall_s"] += wall
        self._aggregate(label, prog)
        self._feed_tracer(label, prog, wall, t0_abs)

    def _phase_row(self, label):
        return self._phases.setdefault(label, {
            "occurrences": 0, "wall_s": 0.0, "dispatches": 0,
            "modeled_bytes": 0.0, "modeled_flops": 0.0, "timed_s": 0.0})

    def _aggregate(self, label, recs):
        ph = self._phase_row(label)
        for r in recs:
            ph["dispatches"] += 1
            ph["modeled_bytes"] += r.modeled_bytes
            ph["modeled_flops"] += r.modeled_flops
            ph["timed_s"] += r.timed_s
            a = self._agg.setdefault((label, r.kernel, r.signature), {
                "dispatches": 0, "modeled_bytes": 0.0, "modeled_flops": 0.0,
                "timed_s": 0.0, "timed_calls": 0, "cfg": None})
            a["dispatches"] += 1
            a["modeled_bytes"] += r.modeled_bytes
            a["modeled_flops"] += r.modeled_flops
            if r.timed_s:
                a["timed_s"] += r.timed_s
                a["timed_calls"] += 1
            if r.cfg is not None:
                a["cfg"] = r.cfg
            self._cum_bytes += r.modeled_bytes
            self._cum_dispatches += 1

    def add_wall(self, name: str, seconds: float):
        """Attribute extra measured wall to a phase after the fact (the
        engine adds the async decode stream-out wait here)."""
        self._phase_row(name)["wall_s"] += max(seconds, 0.0)

    def seed_phase(self, name: str, entries, key: Any = None):
        """Pin a phase program from a modeled kernel account
        (``obs.energy.AccountEntry`` list).  Used for phases whose jitted
        steps never hit the registry (plain-jnp decode): every occurrence
        then replays the account — validated by ``audit_decode_step``."""
        REG = self._registry()
        recs = []
        for e in entries:
            spec = REG[e.kernel]
            rec = DispatchRecord(
                kernel=e.kernel, signature=arg_signature(e.args), cfg=None,
                modeled_flops=float(spec.flops(*e.args)),
                modeled_bytes=float(spec.bytes(*e.args)), phase=name)
            recs.extend([rec] * e.calls)
        self._programs[(name, key)] = recs
        self._pinned.add((name, key))

    @staticmethod
    def _registry():
        from repro.obs.energy import _registry
        return _registry()

    # ------------------------------------------------------------- tracer
    def _feed_tracer(self, label, prog, wall, t0_abs):
        tr = self.tracer
        if tr is None or not prog:
            return
        start = tr.rel(t0_abs)
        by_kernel: Dict[str, Dict[str, float]] = {}
        total_b = 0.0
        for r in prog:
            k = by_kernel.setdefault(r.kernel, {"calls": 0, "bytes": 0.0})
            k["calls"] += 1
            k["bytes"] += r.modeled_bytes
            total_b += r.modeled_bytes
        # one span per kernel name per occurrence, the phase wall split
        # proportionally to modeled bytes (modeled attribution — the host
        # has no per-kernel clocks inside a jitted step)
        t = start
        for kname, k in sorted(by_kernel.items()):
            dur = wall * (k["bytes"] / total_b) if total_b else 0.0
            tr.span(f"kernel:{kname}", "kernels", t, t + dur,
                    phase=label, calls=int(k["calls"]),
                    modeled_bytes=int(k["bytes"]))
            t += dur
        end = start + wall
        tr.counter("streamed_bytes", int(self._cum_bytes), ts=end)
        tr.counter("dispatches", int(self._cum_dispatches), ts=end)

    # ------------------------------------------------------------ inspect
    def reset(self):
        """Clear aggregates and the raw record log.  Memoized/seeded phase
        programs survive (they are structural, not cumulative), as does an
        in-flight ``phase`` context — its occurrence lands in the fresh
        aggregates on exit."""
        self.records = []
        self._agg = {}
        self._phases = {}
        self._cum_bytes = 0.0
        self._cum_dispatches = 0
        for frame in self._stack:
            frame["dispatches"] = []

    def phase_rows(self) -> List[Dict[str, Any]]:
        rows = []
        for label, ph in sorted(self._phases.items()):
            mt = modeled_time_s(ph["modeled_bytes"], ph["modeled_flops"],
                                int(ph["dispatches"]), self.spatz)
            wall = ph["wall_s"]
            rows.append({
                "phase": label,
                "occurrences": int(ph["occurrences"]),
                "dispatches": int(ph["dispatches"]),
                "modeled_bytes": int(ph["modeled_bytes"]),
                "modeled_flops": int(ph["modeled_flops"]),
                "modeled_time_s": mt,
                "wall_s": wall,
                "achieved_bytes_per_s":
                    ph["modeled_bytes"] / wall if wall else 0.0,
                "fraction_of_roofline": mt / wall if wall else 0.0,
                "measured_minus_modeled_s": wall - mt,
            })
        return rows

    def kernel_rows(self) -> List[Dict[str, Any]]:
        rows = []
        for (label, kernel, sig), a in sorted(self._agg.items()):
            mt = modeled_time_s(a["modeled_bytes"], a["modeled_flops"],
                                int(a["dispatches"]), self.spatz)
            row = {
                "phase": label, "kernel": kernel, "signature": sig,
                "dispatches": int(a["dispatches"]),
                "modeled_bytes": int(a["modeled_bytes"]),
                "modeled_flops": int(a["modeled_flops"]),
                "modeled_time_s": mt,
                "cfg": repr(a["cfg"]) if a["cfg"] is not None else None,
            }
            if a["timed_calls"]:
                row["timed_s"] = a["timed_s"]
                row["timed_calls"] = int(a["timed_calls"])
                row["achieved_bytes_per_s"] = \
                    a["modeled_bytes"] * (a["timed_calls"] /
                                          a["dispatches"]) / a["timed_s"]
                row["fraction_of_roofline"] = \
                    mt * (a["timed_calls"] / a["dispatches"]) / a["timed_s"]
            rows.append(row)
        return rows

    def summary(self) -> Dict[str, Any]:
        return {
            "spatz": self.spatz.name,
            "roofline_bytes_per_s": roofline_bytes_per_s(self.spatz),
            "totals": {
                "dispatches": int(self._cum_dispatches),
                "modeled_bytes": int(self._cum_bytes),
            },
            "phases": self.phase_rows(),
            "kernels": self.kernel_rows(),
        }


# ---------------------------------------------------------------- audit
@dataclass
class AuditResult:
    """Measured-vs-modeled decode-step comparison (exact multiset)."""
    ok: bool
    arch: str
    kv_dtype: str
    measured: Dict[Tuple[str, str], int]
    expected: Dict[Tuple[str, str], int]
    measured_bytes: float
    expected_bytes: float
    dispatches: int = 0

    def report(self) -> str:
        lines = [f"dispatch audit {'OK' if self.ok else 'FAILED'}: "
                 f"{self.arch} kv={self.kv_dtype} — "
                 f"{self.dispatches} dispatches, "
                 f"{int(self.measured_bytes):,} B measured vs "
                 f"{int(self.expected_bytes):,} B modeled"]
        if not self.ok:
            m, e = Counter(self.measured), Counter(self.expected)
            for k in sorted(set(m) | set(e)):
                if m.get(k, 0) != e.get(k, 0):
                    lines.append(f"  {k[0]}({k[1]}): measured "
                                 f"{m.get(k, 0)} != modeled {e.get(k, 0)}")
        return "\n".join(lines)


def audit_decode_step(model, *, cache_len: int = 64,
                      page_size: int = 16,
                      temperature: float = 0.0) -> AuditResult:
    """Replay ONE engine decode step (B=1) under a fresh profiler and
    compare its kernel multiset + modeled bytes against
    ``decode_step_account``.

    The step is the engine's own ``make_serve_step`` body, abstractly
    evaluated (``jax.eval_shape`` — no compile, no FLOPs) with
    ``kernel_routing`` active so every projection/norm/unembed/expert
    dispatches its registry kernel.  ``scan_layers`` is forced off (a
    scanned stack traces its body once, under-counting by num_layers).
    int8/int4-quantized models are not auditable this way (the jnp path
    dequantizes in-graph rather than dispatching ``qgemv``); MX-quantized
    models ARE — the routed path dispatches ``mx_qgemv`` /
    ``mx_qgemv_swiglu`` / ``grouped_expert_qgemv``, and the params are
    MX-quantized abstractly (inside ``jax.eval_shape``) so the captured
    signatures carry the fp4/fp8 + E8M0 placeholder shapes.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp

    from repro.models import build_model
    from repro.models import modules as M
    from repro.obs.energy import account_totals, decode_step_account
    from repro.serve.kvcache import PageSpec
    from repro.serve.step import make_serve_step

    cfg, rt = model.cfg, model.rt
    weights = rt.quantize_weights or "none"
    if weights not in ("none", "mx4", "fp8"):
        raise ValueError("audit_decode_step models raw-weight or MX "
                         f"projections; quantize_weights={weights!r} is "
                         "not auditable (the jnp path dequantizes in-graph)")
    kv_dtype = "int8" if rt.kv_cache_dtype == "int8" else "bfloat16"
    if kv_dtype == "int8":
        from repro.quant.tensor import granule
        page_size = -(-page_size // granule()) * granule()

    rt_u = _dc.replace(rt, scan_layers=False, paged_kernel_decode=False)
    model_u = build_model(cfg, rt_u)
    serve = make_serve_step(model_u, temperature=temperature)
    pspec = PageSpec.for_engine(1, cache_len, page_size, None, kv_dtype)
    dt = jnp.dtype(cfg.dtype)

    def one_step(params):
        caches = model_u.init_caches(1, cache_len, dt, page_spec=pspec)
        batch = {"tokens": jnp.zeros((1, 1), jnp.int32),
                 "pos": jnp.full((1,), cache_len // 2, jnp.int32),
                 "sample_nonce": jnp.zeros((1,), jnp.int32),
                 "block_tables": jnp.tile(
                     jnp.arange(pspec.blocks_per_slot, dtype=jnp.int32),
                     (1, 1))}
        return serve(params, batch, caches)

    params = M.unbox(jax.eval_shape(
        lambda: model_u.init(jax.random.PRNGKey(0))))
    if weights != "none":
        from repro.quant import quantize_params
        params = jax.eval_shape(
            lambda p: quantize_params(p, fmt=weights), params)
    prof = DispatchProfiler()
    prof.install()
    try:
        with M.kernel_routing():
            jax.eval_shape(one_step, params)
    finally:
        prof.uninstall()

    measured = Counter((r.kernel, r.signature) for r in prof.records)
    measured_bytes = sum(r.modeled_bytes for r in prof.records)
    entries = decode_step_account(cfg, slots=1, cache_len=cache_len,
                                  page_size=page_size, kv_dtype=kv_dtype,
                                  weights="bfloat16" if weights == "none"
                                  else weights)
    expected: Counter = Counter()
    for e in entries:
        expected[(e.kernel, arg_signature(e.args))] += e.calls
    expected_bytes = account_totals(entries)["bytes"]
    ok = measured == expected and measured_bytes == expected_bytes
    return AuditResult(ok=ok, arch=cfg.name, kv_dtype=kv_dtype,
                       measured=dict(measured), expected=dict(expected),
                       measured_bytes=measured_bytes,
                       expected_bytes=expected_bytes,
                       dispatches=sum(measured.values()))
