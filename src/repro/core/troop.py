"""TROOP as a composable feature: configuration + the four mechanisms.

Paper -> TPU mapping (see DESIGN.md §2):
  (A) decoupled VLSU interfaces  -> ``streams=2``: every streamed operand is
      fetched as two disjoint contiguous half-streams with independent
      BlockSpecs, so two DMAs are in flight per grid step.
  (B) improved chaining          -> the Pallas grid pipeline (compute on
      block i overlaps the fetch of block i+1); ``unroll`` widens the
      per-step work to keep the faster unit saturated (paper §IV-F).
  (C) shadow buffers             -> accumulation in VMEM/SMEM scratch;
      results commit to HBM once per tile, so compute never stalls on the
      output path.
  (D/E) layout / scrambling      -> hardware-aligned tile shapes
      (multiples of the (8..32, 128) layout granule) + pre-tiled weight
      layout so each stream reads disjoint contiguous HBM regions
      (``core.layout``).
  (G) log2 reductions            -> intra-tile tree reductions + cross-tile
      scratch accumulation (and the cross-device LSE-combine for split-K
      decode in ``kernels.ops``).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TroopConfig:
    streams: int = 2          # decoupled memory interfaces (1 = baseline)
    unroll: int = 1           # per-step block multiplier (software pipelining)
    block_n: int = 256        # output-tile rows
    block_k: int = 512        # reduction-tile depth
    scrambled_layout: bool = True   # pre-tiled weights (E)
    interpret: bool = True    # CPU validation mode (TPU: False)

    def validate(self):
        assert self.streams in (1, 2), "paper evaluates 1 or 2 interfaces"
        assert self.unroll in (1, 2, 4)
        return self


BASELINE = TroopConfig(streams=1, unroll=1, scrambled_layout=False)
TROOP = TroopConfig(streams=2, unroll=2, scrambled_layout=True)


def sublane(dtype) -> int:
    """Minor-to-major second dim granule for a dtype on TPU."""
    import jax.numpy as jnp
    return {2: 16, 1: 32}.get(jnp.dtype(dtype).itemsize, 8)


def aligned(dim: int, dtype, lane: bool = False) -> bool:
    return dim % (128 if lane else sublane(dtype)) == 0
