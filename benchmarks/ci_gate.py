"""Perf-regression CI gate: fresh BENCH_*.json vs committed baselines.

The ROADMAP cares about the BENCH trajectory, but a linear CI job only
checks that benchmarks *run* — a regression in compile counts, quantization
error, bytes models or engine completeness lands silently.  This gate
compares freshly produced ``BENCH_tune/serve/quant.json`` against
``benchmarks/baselines/*.json`` under per-metric tolerance bands and fails
the job on regression, printing a markdown delta table (also appended to
``$GITHUB_STEP_SUMMARY`` when set).

Metric classes:

  * ``exact``     — must equal the baseline bit for bit: compile/trace
                    counts (the recompile-free invariants), request
                    completeness, modeled byte counts.  These are
                    hardware-independent and deterministic.
  * ``rel_band``  — |cur - base| <= tol * max(|base|, eps): deterministic
                    ratios (bytes ratios, chunk utilization, prefix hit
                    rate, the analytic predicted-fraction).
  * ``max_rel``   — cur <= base * (1 + tol): one-sided ceilings where
                    *lower is fine* (quantization error).
  * ``info``      — reported, never gated: wall-clock metrics (steps/s,
                    tok/s, TTFT, measured_us) vary across CI hardware; the
                    nightly bench tracks their trajectory as artifacts.

Usage:
    python benchmarks/ci_gate.py                    # gate (CI)
    python benchmarks/ci_gate.py --update           # regenerate baselines
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE_DIR = os.path.join(HERE, "baselines")

EPS = 1e-12

# (file, path, kind, tol) — path segments; [*] fans out over a list, with
# row labels derived from kernel/mode/backend/dtype fields.
GATES = [
    # --- serve: scheduler invariants ------------------------------------
    ("BENCH_serve.json", "engines[*].prefill_traces", "exact", 0),
    ("BENCH_serve.json", "engines[*].all_finished", "exact", 0),
    ("BENCH_serve.json", "engines[*].requests_finished", "exact", 0),
    ("BENCH_serve.json", "engines[*].tokens_generated", "exact", 0),
    ("BENCH_serve.json", "engines[*].chunk_utilization", "rel_band", 0.05),
    ("BENCH_serve.json", "engines[*].prefix_hit_rate", "rel_band", 0.05),
    ("BENCH_serve.json", "engines[*].tokens_per_s", "info", 0),
    ("BENCH_serve.json", "engines[*].ttft_s_mean", "info", 0),
    ("BENCH_serve.json", "engines[*].ttft_s_p95", "info", 0),
    # TP rows: modeled per-device streamed-KV bytes are exact integers
    # (row-bytes model x rows submitted / kv_shards) — a sharding
    # regression that re-streams replicated KV shows up here.
    ("BENCH_serve.json", "engines[*].kv_bytes_streamed", "exact", 0),
    ("BENCH_serve.json", "engines[*].kv_bytes_streamed_per_device",
     "exact", 0),
    # speculative row: a same-arch seed-0 draft accepts 100% of greedy
    # proposals, so acceptance and tokens-per-target-pass are exact — any
    # drift means the draft/verify/rollback machinery changed behavior.
    ("BENCH_serve.json", "engines[*].acceptance_rate", "exact", 0),
    ("BENCH_serve.json", "engines[*].tokens_per_target_pass", "exact", 0),
    ("BENCH_serve.json", "decode_kernels[*].roofline_us", "rel_band", 0.05),
    ("BENCH_serve.json", "decode_kernels[*].measured_us", "info", 0),
    # profiled engine row: per-phase dispatch counts + modeled bytes are
    # deterministic replays of the dispatch programs — exact; the
    # wall-derived roofline fractions track CI hardware and stay info.
    ("BENCH_serve.json", "engines[*].profile[*].occurrences", "exact", 0),
    ("BENCH_serve.json", "engines[*].profile[*].dispatches", "exact", 0),
    ("BENCH_serve.json", "engines[*].profile[*].modeled_bytes", "exact", 0),
    ("BENCH_serve.json", "engines[*].profile[*].fraction_of_roofline",
     "info", 0),
    # --- tune: the analytic model is deterministic ----------------------
    ("BENCH_tune.json", "kernels[*].predicted_fraction", "rel_band", 0.05),
    ("BENCH_tune.json", "kernels[*].fraction_of_roofline", "info", 0),
    # --- quant: bytes models + error ceilings ---------------------------
    ("BENCH_quant.json", "qgemv[*].modeled_bytes", "exact", 0),
    ("BENCH_quant.json", "qgemv[*].bytes_ratio_vs_bf16", "rel_band", 0.01),
    ("BENCH_quant.json", "qgemv[*].max_rel_err_vs_fp32", "max_rel", 0.5),
    ("BENCH_quant.json", "paged_decode[*].modeled_bytes", "exact", 0),
    ("BENCH_quant.json", "paged_decode[*].bytes_ratio_vs_bf16",
     "rel_band", 0.01),
    ("BENCH_quant.json", "engines[*].prefill_traces", "exact", 0),
    ("BENCH_quant.json", "engines[*].requests_finished", "exact", 0),
    ("BENCH_quant.json", "engines[*].tokens_per_s", "info", 0),
    # MX microscaling rows (DESIGN.md §11): the fp4-nibble + E8M0 byte
    # models are exact integers; the acceptance ratios (mx4 <= 0.28x,
    # fp8 <= 0.55x bf16 — asserted inside quant_bench) sit in tight bands.
    ("BENCH_quant.json", "mx4_bytes_ratio", "exact", 0),
    ("BENCH_quant.json", "fp8_bytes_ratio", "exact", 0),
    ("BENCH_quant.json", "mx[*].modeled_bytes", "exact", 0),
    ("BENCH_quant.json", "mx[*].bytes_ratio_vs_bf16", "rel_band", 0.01),
    ("BENCH_quant.json", "mx[*].max_rel_err_vs_fp32", "max_rel", 0.5),
    ("BENCH_quant.json", "mx[*].measured_us", "info", 0),
    # quantized-expert serving: completeness exact, wall tok/s info
    ("BENCH_quant.json", "moe_engines[*].all_finished", "exact", 0),
    ("BENCH_quant.json", "moe_engines[*].requests_finished", "exact", 0),
    ("BENCH_quant.json", "moe_engines[*].tokens_generated", "exact", 0),
    ("BENCH_quant.json", "moe_engines[*].tokens_per_s", "info", 0),
    # modeled energy fold per weight format (deterministic account)
    ("BENCH_quant.json", "energy[*].modeled_bytes_per_step", "exact", 0),
    ("BENCH_quant.json", "energy[*].bytes_per_token", "exact", 0),
    ("BENCH_quant.json", "energy[*].joules_per_token", "rel_band", 0.01),
    # the quantized-MoE decode-step dispatch audit is byte-exact
    ("BENCH_quant.json", "audit[*].match", "exact", 0),
    ("BENCH_quant.json", "audit[*].dispatches", "exact", 0),
    ("BENCH_quant.json", "audit[*].modeled_bytes_measured", "exact", 0),
    ("BENCH_quant.json", "audit[*].modeled_bytes_expected", "exact", 0),
    # --- load: step-clock SLO bands + modeled energy --------------------
    # *_steps latencies count engine cycles under the replayer's virtual
    # clock — deterministic for a seeded trace, so they get bands; *_s
    # metrics are wall clock and stay info-only.
    ("BENCH_load.json", "rows[*].all_finished", "exact", 0),
    ("BENCH_load.json", "rows[*].requests_finished", "exact", 0),
    ("BENCH_load.json", "rows[*].tokens_generated", "exact", 0),
    ("BENCH_load.json", "rows[*].deferrals", "exact", 0),
    ("BENCH_load.json", "rows[*].queue_depth_max", "exact", 0),
    ("BENCH_load.json", "rows[*].ttft_steps_p50", "rel_band", 0.05),
    ("BENCH_load.json", "rows[*].ttft_steps_p95", "rel_band", 0.05),
    ("BENCH_load.json", "rows[*].ttft_steps_p99", "rel_band", 0.05),
    ("BENCH_load.json", "rows[*].wait_steps_p95", "rel_band", 0.05),
    ("BENCH_load.json", "rows[*].tpot_steps_p95", "rel_band", 0.05),
    ("BENCH_load.json", "rows[*].prefix_hit_rate", "rel_band", 0.05),
    ("BENCH_load.json", "rows[*].ttft_s_p95", "info", 0),
    ("BENCH_load.json", "rows[*].tokens_per_s", "info", 0),
    ("BENCH_load.json", "energy[*].modeled_bytes_per_step", "exact", 0),
    ("BENCH_load.json", "energy[*].bytes_per_token", "exact", 0),
    ("BENCH_load.json", "energy[*].joules_per_token", "rel_band", 0.01),
    ("BENCH_load.json", "energy[*].tokens_per_s_per_w", "rel_band", 0.01),
    ("BENCH_load.json", "energy[*].fraction_of_roofline", "rel_band", 0.01),
    # profiler: phase dispatch counts / modeled bytes replay deterministic
    # dispatch programs; the decode-step audit is the measured-vs-modeled
    # invariant (kernel multiset == decode_step_account, byte-exact).
    ("BENCH_load.json", "profile[*].occurrences", "exact", 0),
    ("BENCH_load.json", "profile[*].dispatches", "exact", 0),
    ("BENCH_load.json", "profile[*].modeled_bytes", "exact", 0),
    ("BENCH_load.json", "profile[*].achieved_bytes_per_s", "info", 0),
    ("BENCH_load.json", "profile[*].fraction_of_roofline", "info", 0),
    ("BENCH_load.json", "audit[*].match", "exact", 0),
    ("BENCH_load.json", "audit[*].dispatches", "exact", 0),
    ("BENCH_load.json", "audit[*].modeled_bytes_measured", "exact", 0),
    ("BENCH_load.json", "audit[*].modeled_bytes_expected", "exact", 0),
]


def _label(el, idx):
    if not isinstance(el, dict):
        return str(idx)
    parts = [str(el[k]) for k in ("kernel", "mode", "arch") if k in el][:1]
    parts += [str(el[k]) for k in ("backend", "dtype", "kv_dtype",
                                   "weights", "phase", "engine")
              if k in el and str(el[k]) not in parts]
    return "/".join(parts) if parts else str(idx)


def resolve(doc, path):
    """Expand a dotted path (with [*] list fan-out) -> [(label, value)]."""
    items = [("", doc)]
    for seg in path.split("."):
        out = []
        for label, node in items:
            if seg.endswith("[*]"):
                for i, el in enumerate(node.get(seg[:-3], [])
                                       if isinstance(node, dict) else []):
                    lab = _label(el, i)
                    out.append((f"{label}.{lab}".lstrip("."), el))
            elif isinstance(node, dict) and seg in node:
                out.append((label, node[seg]))
        seen, uniq = {}, []
        for lab, v in out:
            n = seen.get(lab, 0)
            seen[lab] = n + 1
            uniq.append((f"{lab}#{n}" if n else lab, v))
        items = uniq
    return items


def _fmt(v):
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def compare(kind, tol, base, cur):
    """-> (ok, delta_str)."""
    if kind == "info":
        ok = True
    elif kind == "exact":
        ok = base == cur
    elif isinstance(base, (int, float)) and isinstance(cur, (int, float)) \
            and not isinstance(base, bool):
        if kind == "rel_band":
            ok = abs(cur - base) <= tol * max(abs(base), EPS) + EPS
        elif kind == "max_rel":
            ok = cur <= base * (1 + tol) + EPS
        else:
            raise ValueError(kind)
    else:
        ok = base == cur
    if isinstance(base, (int, float)) and not isinstance(base, bool) \
            and isinstance(cur, (int, float)) and base:
        delta = f"{(cur - base) / abs(base) * 100:+.1f}%"
    else:
        delta = "=" if base == cur else "!="
    return ok, delta


def gate(files, baseline_dir, fresh_dir="."):
    rows, failures = [], []
    for fname in files:
        fresh_path = os.path.join(fresh_dir, fname)
        base_path = os.path.join(baseline_dir, fname)
        if not os.path.exists(fresh_path):
            failures.append(f"{fname}: fresh file missing (benchmark did "
                            f"not run?)")
            continue
        if not os.path.exists(base_path):
            failures.append(f"{fname}: no committed baseline — run "
                            f"`python benchmarks/ci_gate.py --update` and "
                            f"commit benchmarks/baselines/")
            continue
        fresh = json.load(open(fresh_path))
        base = json.load(open(base_path))
        for gfile, path, kind, tol in GATES:
            if gfile != fname:
                continue
            b_items = dict(resolve(base, path))
            c_items = dict(resolve(fresh, path))
            for lab, bval in b_items.items():
                metric = f"{path.split('[*]')[-1].lstrip('.')}"
                name = f"{lab}.{metric}" if lab else metric
                if lab not in c_items:
                    rows.append((fname, name, _fmt(bval), "—", "missing",
                                 "FAIL" if kind != "info" else "info"))
                    if kind != "info":
                        failures.append(f"{fname}:{name} missing from "
                                        f"fresh run")
                    continue
                cval = c_items[lab]
                ok, delta = compare(kind, tol, bval, cval)
                status = "info" if kind == "info" else \
                    ("OK" if ok else "FAIL")
                rows.append((fname, name, _fmt(bval), _fmt(cval), delta,
                             status))
                if not ok:
                    failures.append(
                        f"{fname}:{name} {kind}(tol={tol}) baseline="
                        f"{_fmt(bval)} current={_fmt(cval)} ({delta})")
            for lab in c_items:
                if lab not in b_items and kind != "info":
                    metric = path.split("[*]")[-1].lstrip(".")
                    rows.append((fname, f"{lab}.{metric}", "—",
                                 _fmt(c_items[lab]), "new", "info"))
    return rows, failures


def markdown(rows, failures):
    out = ["## BENCH regression gate", "",
           "| file | metric | baseline | current | Δ | status |",
           "|---|---|---|---|---|---|"]
    for fname, name, b, c, d, status in rows:
        mark = {"OK": "✅", "FAIL": "❌", "info": "·"}[status]
        out.append(f"| {fname} | `{name}` | {b} | {c} | {d} | {mark} "
                   f"{status} |")
    out.append("")
    out.append(f"**{'REGRESSION' if failures else 'clean'}** — "
               f"{len([r for r in rows if r[5] == 'FAIL'])} failing / "
               f"{len(rows)} compared")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--files", nargs="*",
                    default=["BENCH_tune.json", "BENCH_serve.json",
                             "BENCH_quant.json", "BENCH_load.json"])
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument("--fresh-dir", default=".")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh BENCH files over the baselines "
                         "(commit the result)")
    args = ap.parse_args(argv)

    if args.update:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for fname in args.files:
            src = os.path.join(args.fresh_dir, fname)
            if not os.path.exists(src):
                print(f"skip {fname}: not present", file=sys.stderr)
                continue
            shutil.copy(src, os.path.join(args.baseline_dir, fname))
            print(f"baseline updated: {fname}")
        return 0

    rows, failures = gate(args.files, args.baseline_dir, args.fresh_dir)
    md = markdown(rows, failures)
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    if failures:
        print("\nregressions:", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
