"""Distributed primitives: collective matmuls, DDP with compressed
gradients, and GPipe pipelining.

The mesh-level mirror of the kernel layer: the paper's decoupled-stream /
overlap ideas applied to inter-chip traffic (ROADMAP north-star: serve and
train at the speed the hardware allows).
"""
from repro.dist.collective_matmul import (allgather_matmul,
                                          reduce_scatter_matmul)
from repro.dist.compression import dequantize_int8, quantize_int8
from repro.dist.ddp import make_ddp_train_step
from repro.dist.pipeline import bubble_fraction, make_pipeline_fn

__all__ = ["allgather_matmul", "reduce_scatter_matmul",
           "quantize_int8", "dequantize_int8",
           "make_ddp_train_step", "make_pipeline_fn", "bubble_fraction"]
