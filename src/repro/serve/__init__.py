from repro.serve.kvcache import (BlockAllocator, CacheBackend, DenseBackend,
                                 PagedBackend, PagedKVCache, PageSpec,
                                 bucket_length, make_backend)
from repro.serve.scheduler import Request, ServingEngine, splice_cache
from repro.serve.step import (make_prefill_step, make_serve_step,
                              sample_keys, tuned_kernel_configs)

__all__ = ["Request", "ServingEngine", "splice_cache",
           "BlockAllocator", "CacheBackend", "DenseBackend", "PagedBackend",
           "PagedKVCache", "PageSpec", "bucket_length", "make_backend",
           "make_prefill_step", "make_serve_step", "sample_keys",
           "tuned_kernel_configs"]
