"""GQA attention (RoPE, optional qkv-bias / qk-norm), KV-cache aware.

Pure-jnp reference path — GSPMD-shardable, used by the multi-pod dry-run and
as the oracle for the Pallas flash/decode kernels.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.core import partitioning as PT
from repro.models import modules as M
from repro.serve.kvcache import (ChunkStage, NULL_PAGE,  # noqa: F401
                                 PagedKVCache, PageSpec)


class KVCache(NamedTuple):
    """KV cache; optionally int8-quantized (k/v int8 + per-(token, head)
    bf16 scales — §Perf A4: halves the decode memory-roofline floor)."""
    k: jax.Array       # (B, S, KV, hd) bf16 | int8
    v: jax.Array       # (B, S, KV, hd)
    k_scale: Optional[jax.Array] = None   # (B, S, KV, 1) bf16 when int8
    v_scale: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


# Thin views over the repro.quant primitives (kept under their historical
# names — §Perf A4 predates the quant subsystem; one absmax implementation
# now serves KV caches, page pools and gradient compression alike).
from repro.quant.tensor import dequantize_kv, quantize_kv  # noqa: E402,F401


def attention_init(key, cfg, *, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": M.dense_init(ks[0], d, H * hd, ("embed", "qkv_out"),
                           bias=cfg.qkv_bias),
        "wk": M.dense_init(ks[1], d, KV * hd, ("embed", "kv_out"),
                           bias=cfg.qkv_bias),
        "wv": M.dense_init(ks[2], d, KV * hd, ("embed", "kv_out"),
                           bias=cfg.qkv_bias),
        "wo": M.dense_init(ks[3], H * hd, d, ("qkv_out", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = M.norm_init("rmsnorm", hd, (None,))
        p["k_norm"] = M.norm_init("rmsnorm", hd, (None,))
    return p


def attend(q, k, v, *, causal: bool, q_offset=0, length: Optional[jax.Array] = None,
           decode: bool = False):
    """q: (B,T,H,hd) k/v: (B,S,KV,hd). GQA via head grouping. fp32 softmax.

    ``q_offset``: absolute position of q[0] (causal masking w/ cache).
    ``length``: valid prefix length of k/v (decode with pre-allocated cache).

    The ``model``-axis strategy (shard KV heads / GQA groups / KV sequence)
    is picked per shape by ``PT.attn_strategy`` — see core.partitioning.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    q = q.reshape(B, T, KV, G, hd)
    strat = PT.attn_strategy(KV, G, decode)
    if strat in ("kv", "kv_uneven"):
        q = PT.constrain(q, ("batch", None, "heads", None, None),
                         allow_uneven=strat == "kv_uneven")
        k = PT.constrain(k, ("batch", None, "heads", None),
                         allow_uneven=strat == "kv_uneven")
        v = PT.constrain(v, ("batch", None, "heads", None),
                         allow_uneven=strat == "kv_uneven")
        score_axes = ("batch", "heads", None, None, None)
        out_axes = ("batch", None, "heads", None, None)
    elif strat == "group":
        q = PT.constrain(q, ("batch", None, None, "heads", None))
        k = PT.constrain(k, ("batch", None, None, None))
        v = PT.constrain(v, ("batch", None, None, None))
        score_axes = ("batch", None, "heads", None, None)
        out_axes = ("batch", None, None, "heads", None)
    elif strat == "seq":
        q = PT.constrain(q, ("batch", None, None, None, None))
        k = PT.constrain(k, ("batch", "attn_kv_seq", None, None))
        v = PT.constrain(v, ("batch", "attn_kv_seq", None, None))
        score_axes = ("batch", None, None, None, "attn_kv_seq")
        out_axes = ("batch", None, None, None, None)
    else:
        score_axes = out_axes = None
    # §Perf A3: in decode the QK/PV contractions stay in the cache dtype —
    # a f32-preferred einsum makes XLA materialize fp32 copies of the WHOLE
    # cache (2 extra O(S) passes/layer; the MXU accumulates in fp32 anyway).
    # Only the small scores tensor is upcast for the fp32 softmax.  Gated on
    # the distributed context: local/CPU paths keep full-fp32 scores.
    qk_dtype = None if (decode and PT.active()) else jnp.float32
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k,
                        preferred_element_type=qk_dtype)
    scores = scores.astype(jnp.float32)
    if score_axes is not None:
        scores = PT.constrain(scores, score_axes,
                              allow_uneven=strat == "kv_uneven")
    scores = scores * (hd ** -0.5)
    spos = jnp.arange(S)[None, None, None, None, :]
    mask = jnp.zeros((), jnp.bool_)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        qpos = q_offset + jnp.arange(T)[None, None, None, :, None]
        mask = spos > qpos
    if length is not None:
        mask = mask | (spos >= length[:, None, None, None, None])
    scores = jnp.where(mask, neg, scores)
    probs = jax.nn.softmax(scores, axis=-1)
    # §Perf B2: pin probs + output shardings. Without these, GSPMD resolves
    # the PV contraction with an "involuntary full rematerialization" of the
    # (B,KV,G,T,S) probs tensor — ~29 all-gathers of 1.07 GB per layer in
    # glm4-9b train_4k (measured; see EXPERIMENTS.md §Perf).
    if score_axes is not None:
        probs = PT.constrain(probs.astype(v.dtype), score_axes,
                             allow_uneven=strat == "kv_uneven")
    else:
        probs = probs.astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    if out_axes is not None:
        out = PT.constrain(out, out_axes,
                           allow_uneven=strat == "kv_uneven")
    return out.reshape(B, T, H, hd)


def _bf16_cache_einsum(spec, a, b):
    """Contraction over a cache operand without upcasting it (A3)."""
    return jnp.einsum(spec, a.astype(b.dtype), b)


def _tp_ctx():
    from repro.dist import tp as _tp
    return _tp.current()


def _tp_merge_heads(out):
    """Exact-TP merge: re-concatenate the per-device head shards (tiled
    all_gather, bitwise) ahead of the replicated output projection.  A
    no-op outside a TP context and in overlap mode (where ``wo`` is
    row-parallel and consumes the local shard directly)."""
    ctx = _tp_ctx()
    if ctx is not None and ctx.mode == "exact":
        from repro.dist import tp as _tp
        return _tp.gather_cols(out)
    return out


def _tp_attend_kv(k, v, cfg):
    """GQA fallback (``kv_shards == 1``): the cache holds every KV head on
    every device — slice the one head this device's query block reads, so
    ``attend``'s shape-derived grouping sees (KV=1, G=local heads)."""
    ctx = _tp_ctx()
    if ctx is not None and ctx.kv_replicated:
        from repro.dist import tp as _tp
        k = _tp.local_kv_head(k, cfg.num_heads, cfg.num_kv_heads)
        v = _tp.local_kv_head(v, cfg.num_heads, cfg.num_kv_heads)
    return k, v


def _project_qkv(p, cfg, x, x_kv, positions, kv_positions, dtype):
    B, T = x.shape[:2]
    hd = cfg.head_dim
    # head counts come from the projection widths, not cfg: under TP the
    # sharded wq/wk/wv emit this device's heads only (wk/wv stay full when
    # the plan replicates KV — fewer KV heads than devices)
    q = M.apply_dense(p["wq"], x, dtype, tp="col").reshape(B, T, -1, hd)
    k = M.apply_dense(p["wk"], x_kv, dtype,
                      tp="col").reshape(B, x_kv.shape[1], -1, hd)
    v = M.apply_dense(p["wv"], x_kv, dtype,
                      tp="col").reshape(B, x_kv.shape[1], -1, hd)
    if cfg.qk_norm:
        q = M.apply_norm(p["q_norm"], q, "rmsnorm", cfg.norm_eps)
        k = M.apply_norm(p["k_norm"], k, "rmsnorm", cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = M.apply_rope(q, positions, cfg.rope_theta)
        k = M.apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def apply_attention(p, cfg, x, *, positions, dtype, causal=True,
                    return_kv=False):
    """Full-sequence (train / prefill) self-attention."""
    q, k, v = _project_qkv(p, cfg, x, x, positions, positions, dtype)
    ka, va = _tp_attend_kv(k, v, cfg)
    out = attend(q, ka, va, causal=causal)
    B, T = x.shape[:2]
    out = _tp_merge_heads(out.reshape(B, T, -1))
    out = M.apply_dense(p["wo"], out, dtype, tp="row")
    # §Perf B3: reduce the TP partial sum HERE, in bf16 — otherwise XLA
    # defers the all-reduce past the next norm's fp32 upcast (2x bytes).
    # §Perf B4: name the post-psum tensor so the remat policy can SAVE it —
    # checkpoint_dots saves the (pre-psum) dot output, so the backward pass
    # re-runs every TP all-reduce otherwise.
    out = PT.constrain(out, ("batch", None, None))
    out = _checkpoint_name(out, "tp_out")
    if return_kv:
        return out, KVCache(k, v)   # k is roped: matches the decode cache
    return out


def update_cache(cache_arr, new, pos):
    """O(1)-byte cache update: scatter the new token row at ``pos``.

    §Perf iterations A1/A2: the naive ``jnp.where(iota == pos, ...)`` reads
    and rewrites the WHOLE cache every step (2 extra O(S) passes/layer).  A
    *global* scatter is worse under GSPMD (it gathers the sharded cache —
    measured, see EXPERIMENTS.md).  The winning form is a shard_map-local
    scatter: each (batch, seq)-shard writes its own rows, indices offset by
    the shard's sequence origin, out-of-range rows dropped — no collectives,
    O(tokens) bytes.
    """
    B, S = cache_arr.shape[:2]
    row = new[:, 0].astype(cache_arr.dtype)

    def local(c, n, p):
        s_local = c.shape[1]
        if PT.active():
            seq_ax = PT.resolve("cache_seq")
            off = jax.lax.axis_index(seq_ax) * s_local if seq_ax else 0
        else:
            off = 0
        idx = p - off
        return c.at[jnp.arange(c.shape[0]), idx].set(n, mode="drop")

    if not PT.active():
        return local(cache_arr, row, pos)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = PT._CTX.mesh
    b_ax = PT.resolve("batch")
    bsz = PT.mesh_size(b_ax)
    if bsz <= 1 or B % bsz:
        b_ax = None
    s_ax = PT.resolve("cache_seq")
    if s_ax is not None and (PT.mesh_size(s_ax) <= 1
                             or S % PT.mesh_size(s_ax) or S < 1024):
        s_ax = None
    trail = (None,) * (cache_arr.ndim - 2)
    cspec = P(b_ax, s_ax, *trail)
    nspec = P(b_ax, *trail)
    pspec = P(b_ax)
    return shard_map(local, mesh=mesh, in_specs=(cspec, nspec, pspec),
                     out_specs=cspec, check_rep=False)(cache_arr, row, pos)


def update_paged_cache(pool, new, pos, block_tables):
    """Paged cache write: route the new token row through the block table.

    pool (P, page, KV, hd); new (B, 1, KV, hd); pos (B,); block_tables
    (B, nblk).  Token ``pos`` of slot ``b`` lives at page
    ``block_tables[b, pos // page]`` row ``pos % page`` — O(tokens) bytes,
    no full-cache rewrite, and (unlike the dense scatter) the write lands in
    a page that is physically disjoint from every other slot's pages.
    """
    page = pool.shape[1]
    row = new[:, 0].astype(pool.dtype)
    pid = jnp.take_along_axis(block_tables, (pos // page)[:, None],
                              axis=1)[:, 0]
    return pool.at[pid, pos % page].set(row, mode="drop")


def update_paged_cache_chunk(pool, new, offset, valid, block_tables):
    """Chunked-prefill cache write: scatter a slab of token rows through the
    block table.

    pool (P, page, KV, hd); new (B, C, KV, hd); offset (B,) absolute
    position of row 0; valid (B,) rows of the slab that are real tokens;
    block_tables (B, nblk).  Row r of slot b lands at page
    ``block_tables[b, (offset+r) // page]`` row ``(offset+r) % page``; pad
    rows (r >= valid) are redirected to the never-read NULL page, so a
    partially filled final chunk cannot clobber live pages — in particular
    never a *shared* prefix page, which by the COW invariant is only ever
    mapped at positions < offset.
    """
    page = pool.shape[1]
    B, C = new.shape[:2]
    pos = offset[:, None] + jnp.arange(C)[None, :]             # (B, C)
    blk = jnp.clip(pos // page, 0, block_tables.shape[1] - 1)
    pid = jnp.take_along_axis(block_tables, blk, axis=1)       # (B, C)
    pid = jnp.where(jnp.arange(C)[None, :] < valid[:, None], pid, NULL_PAGE)
    rows = new.astype(pool.dtype).reshape((B * C,) + new.shape[2:])
    return pool.at[pid.reshape(-1), (pos % page).reshape(-1)].set(
        rows, mode="drop")


def gather_paged_kv(cache: PagedKVCache, block_tables,
                    dtype=jnp.bfloat16):
    """Dense logical view of a paged cache: (B, nblk*page, KV, hd).

    Pure-jnp reference path (the oracle for the Pallas
    ``paged_decode_attention`` kernels, which stream pages directly from
    the pool without materializing this view).  int8 pools are dequantized
    through their gathered scale pages (to ``dtype``).
    """
    B, nblk = block_tables.shape
    page, KV, hd = cache.k_pool.shape[1:]
    k = cache.k_pool[block_tables].reshape(B, nblk * page, KV, hd)
    v = cache.v_pool[block_tables].reshape(B, nblk * page, KV, hd)
    if cache.quantized:
        ks = cache.k_scale_pool[block_tables].reshape(B, nblk * page, KV, 1)
        vs = cache.v_scale_pool[block_tables].reshape(B, nblk * page, KV, 1)
        return dequantize_kv(k, ks, dtype), dequantize_kv(v, vs, dtype)
    return k, v


def apply_attention_decode_paged(p, cfg, x, cache: PagedKVCache, pos,
                                 dtype, block_tables, use_kernel=False):
    """Single-token decode against a paged cache (see serve.kvcache).

    ``use_kernel``: attend through the tuned Pallas
    ``kernels.paged_decode_attention`` (block-table gather inside the
    kernel — the pool is streamed page by page, no dense copy).  Default is
    the jnp reference path, which materializes the gathered logical view
    (full-capacity traffic: fine as oracle / GSPMD path, not the
    at-the-roofline stream — see DESIGN.md §4).
    """
    assert block_tables is not None, \
        "paged caches need batch['block_tables'] in the decode batch"
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(
        p, cfg, x, x, pos[:, None], pos[:, None], dtype)
    if cache.quantized:
        # int8 pools: quantize the new token row and write value + scale
        # pages through the same table entry (§Perf A4 at page granularity)
        k8, ks = quantize_kv(k_new)
        v8, vs = quantize_kv(v_new)
        new_cache = PagedKVCache(
            update_paged_cache(cache.k_pool, k8, pos, block_tables),
            update_paged_cache(cache.v_pool, v8, pos, block_tables),
            update_paged_cache(cache.k_scale_pool, ks, pos, block_tables),
            update_paged_cache(cache.v_scale_pool, vs, pos, block_tables))
    else:
        new_cache = PagedKVCache(
            update_paged_cache(cache.k_pool, k_new, pos, block_tables),
            update_paged_cache(cache.v_pool, v_new, pos, block_tables))
    if use_kernel:
        from repro.kernels import ops as KO   # lazy: keeps models jnp-only
        if cache.quantized:
            out = KO.paged_decode_attention_int8(   # dispatches via tune
                q[:, 0], new_cache.k_pool, new_cache.k_scale_pool,
                new_cache.v_pool, new_cache.v_scale_pool, block_tables,
                pos + 1)[:, None]
        else:
            out = KO.paged_decode_attention(        # dispatches via tune
                q[:, 0], new_cache.k_pool, new_cache.v_pool, block_tables,
                pos + 1)[:, None]
    else:
        k, v = gather_paged_kv(new_cache, block_tables, dtype)
        k, v = _tp_attend_kv(k, v, cfg)
        out = attend(q, k, v, causal=False, length=pos + 1, decode=True)
    out = _tp_merge_heads(out.reshape(B, 1, -1))
    out = M.apply_dense(p["wo"], out, dtype, tp="row")
    return out, new_cache


def apply_attention_chunk_paged(p, cfg, x, cache: PagedKVCache, offset,
                                valid, stage_base, dtype, block_tables,
                                stage: Optional[ChunkStage] = None,
                                use_kernel=False):
    """Chunked-prefill attention against a paged cache.

    ``x`` (B, C, d) is one fixed-size slab of prompt tokens starting at
    absolute position ``offset`` (B,), of which the first ``valid`` (B,)
    rows are real; the slab's KV is written through the block table, then
    the slab attends causally over positions [0, offset + valid) — shared
    prefix pages included, so a prefix-cache hit starts mid-prompt with
    ``offset`` > 0 and never recomputes the shared rows.

    ``stage`` (int8 pools only) keeps this request's own prefill rows in
    bf16 so later chunks do not re-read their predecessors through the
    quantized pages — the chunked engine stays token-identical to the
    bucketed one (see ``kvcache.ChunkStage``).  Rows below ``stage_base``
    (a shared prefix) predate this request and are read from the pages.

    Returns (out (B, C, d), new_cache, new_stage_or_None).
    """
    assert block_tables is not None, \
        "chunked prefill needs batch['block_tables']"
    B, C = x.shape[:2]
    positions = offset[:, None] + jnp.arange(C)[None, :]
    q, k_new, v_new = _project_qkv(p, cfg, x, x, positions, positions, dtype)
    if cache.quantized:
        k8, ks = quantize_kv(k_new)
        v8, vs = quantize_kv(v_new)
        new_cache = PagedKVCache(
            update_paged_cache_chunk(cache.k_pool, k8, offset, valid,
                                     block_tables),
            update_paged_cache_chunk(cache.v_pool, v8, offset, valid,
                                     block_tables),
            update_paged_cache_chunk(cache.k_scale_pool, ks, offset, valid,
                                     block_tables),
            update_paged_cache_chunk(cache.v_scale_pool, vs, offset, valid,
                                     block_tables))
    else:
        new_cache = PagedKVCache(
            update_paged_cache_chunk(cache.k_pool, k_new, offset, valid,
                                     block_tables),
            update_paged_cache_chunk(cache.v_pool, v_new, offset, valid,
                                     block_tables))
    length = offset + valid
    new_stage = None
    if use_kernel and not cache.quantized:
        from repro.kernels import ops as KO   # lazy: keeps models jnp-only
        out = KO.prefill_attention_paged(
            q, new_cache.k_pool, new_cache.v_pool, block_tables, offset,
            length)
    else:
        k, v = gather_paged_kv(new_cache, block_tables, dtype)
        if stage is not None:
            # overlay this request's own bf16 rows (positions in
            # [stage_base, offset + valid)) on the dequantized view
            new_stage = ChunkStage(
                jax.lax.dynamic_update_slice(
                    stage.k, k_new.astype(stage.k.dtype),
                    (0, offset[0], 0, 0)),
                jax.lax.dynamic_update_slice(
                    stage.v, v_new.astype(stage.v.dtype),
                    (0, offset[0], 0, 0)))
            S = k.shape[1]
            spos = jnp.arange(S)[None, :]
            use = ((spos >= stage_base[:, None])
                   & (spos < length[:, None]))[:, :, None, None]
            k = jnp.where(use, new_stage.k[:, :S].astype(k.dtype), k)
            v = jnp.where(use, new_stage.v[:, :S].astype(v.dtype), v)
        k, v = _tp_attend_kv(k, v, cfg)
        out = attend(q, k, v, causal=True,
                     q_offset=offset[:, None, None, None, None],
                     length=length)
    out = _tp_merge_heads(out.reshape(B, C, -1))
    out = M.apply_dense(p["wo"], out, dtype, tp="row")
    if stage is not None and new_stage is None:   # kernel path keeps stage
        new_stage = stage
    return out, new_cache, new_stage


def apply_attention_decode(p, cfg, x, cache, pos, dtype, block_tables=None,
                           use_kernel=False):
    """Single-token decode. ``pos``: (B,) current position; cache has fixed S."""
    if isinstance(cache, PagedKVCache):
        return apply_attention_decode_paged(p, cfg, x, cache, pos, dtype,
                                            block_tables, use_kernel)
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(
        p, cfg, x, x, pos[:, None], pos[:, None], dtype)
    cs = ("batch", "cache_seq", None, None)
    if cache.quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache = KVCache(
            PT.constrain(update_cache(cache.k, kq, pos), cs),
            PT.constrain(update_cache(cache.v, vq, pos), cs),
            update_cache(cache.k_scale, ks, pos),
            update_cache(cache.v_scale, vs, pos))
        k = dequantize_kv(new_cache.k, new_cache.k_scale, dtype)
        v = dequantize_kv(new_cache.v, new_cache.v_scale, dtype)
    else:
        k = PT.constrain(update_cache(cache.k, k_new, pos), cs)
        v = PT.constrain(update_cache(cache.v, v_new, pos), cs)
        new_cache = KVCache(k, v)
    k, v = _tp_attend_kv(k, v, cfg)
    out = attend(q, k, v, causal=False, length=pos + 1, decode=True)
    out = _tp_merge_heads(out.reshape(B, 1, -1))
    out = M.apply_dense(p["wo"], out, dtype, tp="row")
    return out, new_cache


def apply_cross_attention(p, cfg, x, enc_kv, dtype):
    """Cross-attention over precomputed encoder K/V (whisper decoder)."""
    B, T = x.shape[:2]
    H, hd = cfg.num_heads, cfg.head_dim
    q = M.apply_dense(p["wq"], x, dtype).reshape(B, T, H, hd)
    out = attend(q, enc_kv.k, enc_kv.v, causal=False)
    return M.apply_dense(p["wo"], out.reshape(B, T, -1), dtype)


def cross_kv(p, cfg, enc_out, dtype) -> KVCache:
    B, S = enc_out.shape[:2]
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = M.apply_dense(p["wk"], enc_out, dtype).reshape(B, S, KV, hd)
    v = M.apply_dense(p["wv"], enc_out, dtype).reshape(B, S, KV, hd)
    return KVCache(k, v)


def init_paged_cache(cfg, spec: PageSpec, dtype) -> PagedKVCache:
    """Zeroed page pools for one attention sublayer (shared across slots).

    ``spec.kv_dtype == "int8"`` allocates int8 value pools plus bf16 scale
    pages (same page indices — the allocator is oblivious to them)."""
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (spec.num_pages, spec.page_size, KV, hd)
    if jnp.dtype(spec.kv_dtype) == jnp.dtype(jnp.int8):
        sshape = shape[:-1] + (1,)
        return PagedKVCache(jnp.zeros(shape, jnp.int8),
                            jnp.zeros(shape, jnp.int8),
                            jnp.ones(sshape, jnp.bfloat16),
                            jnp.ones(sshape, jnp.bfloat16))
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def init_cache(cfg, B: int, S: int, dtype, quantized: bool = False) -> KVCache:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (B, S, KV, hd)
    if quantized:
        return KVCache(jnp.zeros(shape, jnp.int8),
                       jnp.zeros(shape, jnp.int8),
                       jnp.ones((B, S, KV, 1), jnp.bfloat16),
                       jnp.ones((B, S, KV, 1), jnp.bfloat16))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
