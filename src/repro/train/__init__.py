from repro.train.step import make_eval_step, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["make_train_step", "make_eval_step", "Trainer", "TrainerConfig"]
