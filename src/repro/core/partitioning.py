"""Logical-axis partitioning: rules, activation constraints, spec builders.

Parameters carry logical axes (see ``models.modules.Param``); activations are
pinned inside model code via ``constrain(x, axes)`` which resolves logical
axes -> mesh axes through the active rule set.  Outside a
``activation_rules(mesh, rules)`` context (e.g. CPU smoke tests) every
constraint is a no-op, so model code never depends on a mesh being present.

Attention picks its ``model``-axis strategy per-config:
  kv-heads divisible  -> shard KV heads        (classic Megatron)
  q-groups divisible  -> shard GQA groups      (few-KV-head archs, e.g. glm4)
  otherwise           -> shard the KV sequence (context / sequence parallel;
                         softmax + PV contraction become collectives)
Decode always uses the sequence path over the cache (flash-decode SP).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TRAIN_RULES = {
    "batch": ("pod", "data"),
    "embed": "data",            # FSDP / ZeRO-3 axis for weights
    "ffn": "model",
    "qkv_out": "model",
    "kv_out": "model",
    "vocab": "model",
    "expert": "model",
    "expert_ff": None,
    "kv_lora": None,
    "inner": "model",
    "layers": None,
    "heads": "model",           # activation head dim
    "attn_kv_seq": "model",     # context-parallel fallback / decode SP
    "cache_seq": "model",
}

DECODE_RULES = dict(TRAIN_RULES)
DECODE_RULES.update({
    "embed": None,              # weights stay resident (TP only)
    "heads": None,              # decode shards the cache seq instead
})


def wide_tp_rules(rules):
    """B=1 long-context decode: fold the idle data axis into TP."""
    out = dict(rules)
    for ax in ("ffn", "qkv_out", "kv_out", "inner", "vocab"):
        out[ax] = ("data", "model")
    return out


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[dict] = None


_CTX = _Ctx()


@contextlib.contextmanager
def activation_rules(mesh: Mesh, rules: dict):
    old = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = old


def active() -> bool:
    return _CTX.mesh is not None


def mesh_size(axis, mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or _CTX.mesh
    if axis is None or mesh is None:
        return 1
    axes = (axis,) if isinstance(axis, str) else axis
    n = 1
    for a in axes:
        if a not in mesh.axis_names:
            return 0
        n *= mesh.shape[a]
    return n


def resolve(logical: Optional[str], rules: Optional[dict] = None):
    rules = rules or _CTX.rules
    if logical is None or rules is None:
        return None
    rule = rules.get(logical)
    if rule is None:
        return None
    if isinstance(rule, tuple):
        rule = tuple(a for a in rule if a in _CTX.mesh.axis_names)
        return rule or None
    return rule if rule in _CTX.mesh.axis_names else None


def constrain(x, axes, *, allow_uneven: bool = False):
    """Pin activation sharding. axes: tuple of logical names (None entries ok)."""
    if not active():
        return x
    entries = []
    for name, dim in zip(axes, x.shape):
        rule = resolve(name)
        size = mesh_size(rule)
        if rule is None or size <= 1:
            entries.append(None)
        elif dim % size == 0 or (allow_uneven and dim >= size):
            entries.append(rule)
        else:
            entries.append(None)
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def attn_strategy(KV: int, G: int, decode: bool = False) -> str:
    """'kv' | 'group' | 'seq' | 'none' — which dim takes the heads axis."""
    if not active():
        return "none"
    hs = mesh_size(resolve("heads"))
    if hs > 1 and not decode:
        if KV % hs == 0:
            return "kv"
        if G % hs == 0:
            return "group"
    ss = mesh_size(resolve("attn_kv_seq"))
    if ss > 1:
        return "seq"
    if hs > 1 and KV >= hs:
        return "kv_uneven"
    return "none"


def spec_for(axes, shape, rules, mesh) -> P:
    """PartitionSpec for a parameter (strict divisibility)."""
    entries = []
    for ax_name, dim in zip(axes, shape):
        rule = rules.get(ax_name) if ax_name else None
        if isinstance(rule, tuple):
            rule = tuple(a for a in rule if a in mesh.axis_names) or None
        if rule is not None and not isinstance(rule, tuple) \
                and rule not in mesh.axis_names:
            rule = None
        size = mesh_size(rule, mesh)
        if rule is None or size <= 1 or dim % size:
            entries.append(None)
        else:
            entries.append(rule)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)
