"""Fault-tolerant training driver: data + checkpoint + watchdog + restart.

The loop the launcher runs.  Structure (per DESIGN.md §4):
  * deterministic sharded data (restart-safe by construction),
  * periodic async checkpoints (atomic, keep-k),
  * failure handling: SimulatedFailure (tests) or any step exception
    triggers restore-from-latest and continue — optionally onto a SHRUNK
    mesh (elastic: lost data rows fold away, weights re-shard on restore),
  * straggler watchdog escalates to the same checkpoint-restart path.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.ft import FailureInjector, SimulatedFailure, StepWatchdog
from repro.models import modules as M
from repro.optim import OptConfig
from repro.train.step import make_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    max_restarts: int = 3
    async_ckpt: bool = True


class Trainer:
    def __init__(self, model, opt_cfg: OptConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, *, shard_fn: Callable = None,
                 failure_injector: Optional[FailureInjector] = None):
        self.model = model
        self.tcfg = tcfg
        self.data = SyntheticLM(data_cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep,
                                      async_save=tcfg.async_ckpt)
        self.watchdog = StepWatchdog()
        self.injector = failure_injector or FailureInjector()
        self.shard_fn = shard_fn or (lambda tree: tree)
        self.step_fn, self.opt = make_train_step(model, opt_cfg)
        self.step_fn = jax.jit(self.step_fn, donate_argnums=(0, 1))
        self.metrics_history = []

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        boxed = self.model.init(jax.random.PRNGKey(seed))
        params = self.shard_fn(M.unbox(boxed))
        opt_state = self.opt.init(params)
        return params, opt_state, 0

    def _restore(self, params_like, opt_like):
        step = self.ckpt.latest_step()
        if step is None:
            return None
        (params, opt_state), extra = self.ckpt.restore(
            (params_like, opt_like))
        log.warning("restored checkpoint at step %d", step)
        self.data.set_step(extra.get("data_step", step))
        return params, opt_state, step

    # ------------------------------------------------------------------
    def run(self):
        params, opt_state, start = self.init_state()
        restored = self._restore(params, opt_state)
        if restored:
            params, opt_state, start = restored
            self.data.set_step(start)
        restarts = 0
        step = start
        while step < self.tcfg.total_steps:
            try:
                batch = {k: jax.numpy.asarray(v)
                         for k, v in self.data.batch_at(step).items()}
                self.injector.check(step)
                self.watchdog.start()
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch)
                escalate = self.watchdog.stop(step)
                if escalate:
                    raise SimulatedFailure(
                        f"straggler watchdog escalation at step {step}")
                step += 1
                if step % self.tcfg.log_every == 0 or \
                        step == self.tcfg.total_steps:
                    m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                    m["step"] = step
                    self.metrics_history.append(m)
                    log.info("step %d: %s", step, m)
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, (params, opt_state),
                                   extra={"data_step": step})
            except SimulatedFailure as e:
                restarts += 1
                log.warning("FAILURE: %s (restart %d)", e, restarts)
                if restarts > self.tcfg.max_restarts:
                    raise
                self.ckpt.wait()
                restored = self._restore(params, opt_state)
                if restored is None:          # no checkpoint yet: restart
                    params, opt_state, step = self.init_state()
                else:
                    params, opt_state, step = restored
        self.ckpt.wait()
        self.ckpt.save(step, (params, opt_state), extra={"data_step": step})
        self.ckpt.wait()
        return params, opt_state, self.metrics_history
