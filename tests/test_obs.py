"""repro.obs: seeded workload determinism, tracer event ordering +
allocator balance, Chrome-trace export structure, replay determinism,
energy-accounting identity vs the tune registry, engine metrics edge
cases, and the ci_gate SLO bands on BENCH_load rows."""
import json
import os

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve import EngineConfig
from repro.serve.kvcache import PagedBackend
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step


def setup():
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def make_engine(model, params, *, tracer=None, prefix=True, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("cache_len", 64)
    return ServingEngine(
        model, prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params,
        backend=PagedBackend(page_size=16), tracer=tracer,
        config=EngineConfig(backend="paged", chunked_prefill=True,
                            chunk_size=16, prefix_cache=prefix, **kw))


# --------------------------------------------------------------------------
# workload traces
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dist", obs.DISTRIBUTIONS)
def test_workload_seeded_determinism(dist):
    a = obs.generate(dist, requests=40, seed=7)
    b = obs.generate(dist, requests=40, seed=7)
    assert a.entries == b.entries
    c = obs.generate(dist, requests=40, seed=8)
    assert c.entries != a.entries


@pytest.mark.parametrize("dist", obs.DISTRIBUTIONS)
def test_workload_shapes_and_clamps(dist):
    tr = obs.generate(dist, requests=50, seed=1, prompt_len=(4, 48),
                      max_new=(2, 16), num_prefixes=3)
    assert len(tr) == 50
    arr = [e.arrival for e in tr]
    assert arr == sorted(arr) and arr[0] >= 0
    for e in tr:
        assert 4 <= e.prompt_len <= 48
        assert 2 <= e.max_new <= 16
        assert -1 <= e.prefix_id < 3


def test_workload_jsonl_roundtrip(tmp_path):
    tr = obs.generate("bursty", requests=12, seed=3)
    p = str(tmp_path / "trace.jsonl")
    tr.to_jsonl(p)
    back = obs.WorkloadTrace.from_jsonl(p)
    assert back.entries == tr.entries
    assert back.meta == tr.meta


def test_materialize_deterministic_and_shares_prefixes():
    tr = obs.generate("heavy_tail", requests=24, seed=5,
                      prefix_fraction=1.0, num_prefixes=2,
                      prompt_len=(30, 48))
    a = tr.materialize(128, prefix_len=16)
    b = tr.materialize(128, prefix_len=16)
    for (ta, ra), (tb, rb) in zip(a, b):
        assert ta == tb and np.array_equal(ra.prompt, rb.prompt)
    # same prefix_id -> identical leading tokens
    by_pid = {}
    for e, (_, r) in zip(tr, a):
        by_pid.setdefault(e.prefix_id, []).append(r.prompt[:16])
    for heads in by_pid.values():
        for h in heads[1:]:
            assert np.array_equal(h, heads[0])


def test_unknown_distribution_raises():
    with pytest.raises(ValueError, match="unknown distribution"):
        obs.generate("uniform")


# --------------------------------------------------------------------------
# tracer
# --------------------------------------------------------------------------
def test_tracer_ring_capacity_and_counts():
    tr = obs.Tracer(capacity=8)
    for i in range(12):
        tr.instant("tick", "queue", rid=i)
    assert len(tr.events()) == 8
    assert tr.dropped == 4
    assert [e[4] for e in tr.events()] == list(range(4, 12))
    assert tr.counts() == {"tick": 8}
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_tracer_sum_arg_and_chrome_export(tmp_path):
    tr = obs.Tracer()
    tr.instant("page_alloc", "allocator", pages=3)
    tr.instant("page_alloc", "allocator", pages=2)
    tr.span("request", 0, 0.001, 0.005, rid=7, generated=4)
    tr.counter("queue_depth", 5)
    assert tr.sum_arg("page_alloc", "pages") == 5
    p = str(tmp_path / "t.json")
    tr.to_chrome(p)
    doc = json.load(open(p))
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)
    # slot 0 gets a named thread track
    assert any(e["name"] == "thread_name"
               and e["args"]["name"] == "slot 0" for e in meta)
    span = next(e for e in evs if e["ph"] == "X")
    assert span["name"] == "request" and span["args"]["rid"] == 7
    assert span["dur"] == pytest.approx(4000.0)      # 4 ms in us
    ctr = next(e for e in evs if e["ph"] == "C")
    assert ctr["args"] == {"value": 5}


def test_tracer_jsonl_export(tmp_path):
    tr = obs.Tracer()
    tr.instant("submit", "queue", rid=1, prompt_len=9)
    tr.span("chunk", 2, 0.0, 0.002, rid=1, off=0, valid=9)
    p = str(tmp_path / "t.jsonl")
    tr.to_jsonl(p)
    recs = [json.loads(line) for line in open(p)]
    assert recs[0]["name"] == "submit" and recs[0]["args"]["prompt_len"] == 9
    assert recs[1]["ph"] == "X" and recs[1]["dur_us"] == pytest.approx(2000)


# --------------------------------------------------------------------------
# engine lifecycle tracing + replay
# --------------------------------------------------------------------------
def test_traced_soak_spans_close_and_allocator_balances():
    cfg, model, params = setup()
    tracer = obs.Tracer()
    eng = make_engine(model, params, tracer=tracer)
    trace = obs.generate("heavy_tail", requests=10, seed=0,
                         prompt_len=(4, 40), max_new=(2, 6))
    rep = obs.Replayer(eng, prefix_len=16).run(trace, vocab_size=128)
    assert rep.row()["all_finished"]
    c = tracer.counts()
    # every lifecycle stage fired, and per-request events are 1:1
    assert c["submit"] == c["admit"] == c["first_token"] == c["finish"] \
        == c["request"] == 10
    # ordering per rid: submit <= admit <= first_token <= finish
    for open_name, close_name in (("submit", "admit"),
                                  ("admit", "first_token"),
                                  ("first_token", "finish")):
        opened, closed = obs.span_pairs(tracer.events(), open_name,
                                        close_name)
        assert set(opened) == set(closed) == set(range(10))
        for rid in opened:
            assert opened[rid] <= closed[rid]
    # allocator balance: alloc - free == pages still held (prefix index)
    alloc = eng.backend.allocator
    in_use = alloc.num_pages - 1 - alloc.num_free
    assert tracer.sum_arg("page_alloc", "pages") \
        - tracer.sum_arg("page_free", "pages") == in_use
    # dropping the index's references drains the pool to empty — and the
    # traced alloc/free totals then balance exactly
    eng.backend.prefix_index.clear()
    assert alloc.num_free == alloc.num_pages - 1
    assert tracer.sum_arg("page_alloc", "pages") == \
        tracer.sum_arg("page_free", "pages")


def test_replay_step_metrics_deterministic():
    cfg, model, params = setup()
    trace = obs.generate("bursty", requests=8, seed=2, prompt_len=(4, 30),
                         max_new=(2, 5))
    rows = []
    for _ in range(2):
        eng = make_engine(model, params)
        rep = obs.Replayer(eng, prefix_len=16).run(trace, vocab_size=128)
        row = rep.row()
        # wall-clock-derived values (seconds, overlap fraction) vary run
        # to run; everything else must be bit-identical
        rows.append({k: v for k, v in row.items()
                     if not k.endswith("_s") and "_s_" not in k
                     and k != "dispatch_overlap_fraction"})
    assert rows[0] == rows[1]
    assert rows[0]["all_finished"]


def test_replayer_rejects_unknown_clock():
    cfg, model, params = setup()
    eng = make_engine(model, params)
    with pytest.raises(ValueError, match="clock"):
        obs.Replayer(eng, clock="simulated")


# --------------------------------------------------------------------------
# engine metrics edge cases (satellites)
# --------------------------------------------------------------------------
def test_metrics_exclude_zero_decode_requests_and_percentiles():
    cfg, model, params = setup()
    eng = make_engine(model, params, prefix=False)
    # max_new=1: the request finishes on its prefill-emitted first token —
    # it has a TTFT but NO decode rate; it must not drag the decode mean
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(1, 6, dtype=np.int32),
                           max_new_tokens=1))
    eng.submit(Request(rid=3, prompt=np.arange(1, 6, dtype=np.int32),
                       max_new_tokens=4))
    eng.run_until_drained()
    m = eng.metrics()
    assert m["requests_finished"] == 4
    assert len(eng._ttfts) == 4                  # every request has a TTFT
    assert len(eng._decode_rates) == 1           # only the multi-token one
    assert m["decode_tok_s_mean"] > 0.0
    assert m["decode_tok_s_p95"] > 0.0
    assert 0.0 < m["ttft_s_p50"] <= m["ttft_s_p95"]
    assert m["deferrals"] == 0


def test_reset_metrics_preserves_nonce_and_bounds_windows():
    cfg, model, params = setup()
    eng = make_engine(model, params, prefix=False, metrics_window=2)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(1, 6, dtype=np.int32),
                           max_new_tokens=3))
    eng.run_until_drained()
    assert eng.requests_finished == 5
    # the window bounds growth: only the trailing 2 samples are kept
    assert len(eng._ttfts) == 2 and len(eng._decode_rates) == 2
    seq, steps = eng._admission_seq, eng.steps
    eng.reset_metrics()
    assert eng.requests_finished == 0 and eng.tokens_generated == 0
    assert len(eng._ttfts) == 0
    assert eng.metrics()["decode_steps"] == 0
    # scheduling state is NOT a metric: the step counter keeps counting and
    # the admission sequence (the sampling-nonce source) never rewinds —
    # a slot reused after a reset must not replay its predecessor's RNG
    assert eng._admission_seq == seq == 5
    assert eng.steps == steps
    eng.submit(Request(rid=9, prompt=np.arange(1, 6, dtype=np.int32),
                       max_new_tokens=3))
    eng.run_until_drained()
    assert eng._admission_seq == 6
    assert eng.requests_finished == 1


# --------------------------------------------------------------------------
# energy attribution
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kv_dtype,weights", [("bfloat16", "bfloat16"),
                                              ("int8", "int8")])
def test_energy_account_bytes_match_streamed_operands(kv_dtype, weights):
    """The audit identity, per account entry: the registry ``bytes=`` model
    must equal ``operand_bytes`` of the ``streamed=`` operand list at the
    account's exact serving shapes."""
    from repro.obs.energy import _registry
    from repro.tune.registry import operand_bytes

    cfg, _, _ = setup()
    REG = _registry()
    entries = obs.decode_step_account(cfg, slots=3, cache_len=64,
                                      kv_dtype=kv_dtype, weights=weights)
    assert entries, "empty account"
    for e in entries:
        spec = REG[e.kernel]
        assert spec.streamed is not None, e.kernel
        assert spec.bytes(*e.args) == pytest.approx(
            operand_bytes(spec.streamed(*e.args))), e.kernel


def test_energy_int8_cuts_bytes_and_energy():
    cfg, _, _ = setup()
    bf = obs.engine_energy_row(cfg, slots=3, cache_len=64)
    q8 = obs.engine_energy_row(cfg, slots=3, cache_len=64,
                               kv_dtype="int8", weights="int8")
    assert q8["bytes_per_token"] < 0.6 * bf["bytes_per_token"]
    assert q8["joules_per_token"] < bf["joules_per_token"]
    assert q8["tokens_per_s_per_w"] > bf["tokens_per_s_per_w"]
    for row in (bf, q8):
        assert 0.0 < row["fraction_of_roofline"] <= 1.0
        assert row["per_kernel"][0]["bytes_share"] <= 1.0
        # attribution shares sum to 1
        assert sum(k["bytes_share"] for k in row["per_kernel"]) \
            == pytest.approx(1.0, abs=2e-3)


def test_energy_rejects_non_attention_mixers():
    cfg = reduced(get_config("jamba-v0.1-52b"))     # mamba-mixer layers
    with pytest.raises(ValueError, match="mixer"):
        obs.decode_step_account(cfg, slots=2, cache_len=64)


def test_energy_constants_shared_with_table2():
    """One set of Table-II constants: ``benchmarks/table2_energy.py`` must
    import them from ``repro.obs.energy``, not duplicate the literals."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = open(os.path.join(here, "benchmarks", "table2_energy.py")).read()
    assert "from repro.obs.energy import" in src
    assert "P_STATIC = " not in src          # no duplicated constants


# --------------------------------------------------------------------------
# ci_gate SLO bands
# --------------------------------------------------------------------------
def _load_ci_gate():
    import importlib.util
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "ci_gate", os.path.join(here, "benchmarks", "ci_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_ci_gate_fails_on_injected_p95_regression(tmp_path):
    gate = _load_ci_gate()
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base_path = os.path.join(here, "benchmarks", "baselines",
                             "BENCH_load.json")
    base = json.load(open(base_path))
    bdir = tmp_path / "baselines"
    fdir = tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    json.dump(base, open(bdir / "BENCH_load.json", "w"))

    # the committed baseline passes against itself
    json.dump(base, open(fdir / "BENCH_load.json", "w"))
    _, failures = gate.gate(["BENCH_load.json"], str(bdir), str(fdir))
    assert failures == []

    # +50% TTFT p95 on one row -> the SLO band trips
    bad = json.loads(json.dumps(base))
    bad["rows"][-1]["ttft_steps_p95"] *= 1.5
    json.dump(bad, open(fdir / "BENCH_load.json", "w"))
    _, failures = gate.gate(["BENCH_load.json"], str(bdir), str(fdir))
    assert any("ttft_steps_p95" in f for f in failures)

    # a changed modeled byte count is an exact-gate failure
    bad = json.loads(json.dumps(base))
    bad["energy"][0]["bytes_per_token"] += 1
    json.dump(bad, open(fdir / "BENCH_load.json", "w"))
    _, failures = gate.gate(["BENCH_load.json"], str(bdir), str(fdir))
    assert any("bytes_per_token" in f for f in failures)

    # wall-clock is info-only: a 10x tokens/s swing does NOT fail
    bad = json.loads(json.dumps(base))
    for row in bad["rows"]:
        row["tokens_per_s"] *= 10
    json.dump(bad, open(fdir / "BENCH_load.json", "w"))
    _, failures = gate.gate(["BENCH_load.json"], str(bdir), str(fdir))
    assert failures == []
