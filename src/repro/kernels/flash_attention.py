"""Flash attention (prefill/training fwd) — the GEMM-class control kernel.

The paper's Table II requires that TROOP *not* regress compute-bound
kernels; this tiled causal-attention forward is the high-OI counterpart used
by the benchmark harness to demonstrate parity (its roofline term is compute,
not memory).  Standard online-softmax tiling with (q-tile x kv-tile) MXU
matmuls; per-tile state in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel

_NEG = -1e30


def _example(small: bool = True):
    B, T, H, KV, hd, S = (1, 128, 4, 2, 64, 128) if small \
        else (2, 512, 8, 2, 64, 512)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    return (q, k, v), {"causal": True}


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc, *,
            scale, bq, bs, causal):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    q = q_ref[0].astype(jnp.float32)                      # (bq, KV, G, hd)
    bqd, KV, G, hd = q.shape
    k = jnp.moveaxis(k_ref[0], 1, 0).astype(jnp.float32)  # (KV, bs, hd)
    v = jnp.moveaxis(v_ref[0], 1, 0).astype(jnp.float32)
    qr = jnp.moveaxis(q, 1, 0).reshape(KV, bqd * G, hd)
    s = jax.lax.dot_general(qr, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(KV, bqd, G, bs)
    if causal:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        spos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
        s = jnp.where(spos > qpos, _NEG, s)
    m_new = jnp.maximum(m_s[...], jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_s[...] - m_new)
    p = jnp.exp(s - m_new)                                # (KV,bq,G,bs)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, -1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(KV, bqd * G, bs), v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(KV, bqd, G, hd)
    acc[...] = acc[...] * alpha + pv
    m_s[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        out = acc[...] / jnp.maximum(l_s[...], 1e-30)     # (KV,bq,G,hd)
        o_ref[0] = jnp.moveaxis(out, 0, 1).astype(o_ref.dtype)


@troop_kernel(
    "flash_attention",
    flops=lambda q, k, v: (4.0 * q.shape[0] * q.shape[1] * k.shape[1]
                           * q.shape[2] * q.shape[3]),
    bytes=lambda q, k, v: (
        2 * q.shape[0] * q.shape[1] * q.shape[2] * q.shape[3] * itemsize(q)
        + k.shape[0] * k.shape[1] * k.shape[2] * k.shape[3]
        * (itemsize(k) + itemsize(v))),
    streamed=lambda q, k, v: [q, q, k, v],   # q in + q-shaped out + cache
    space={"unroll": (1, 2), "block_k": (256, 512)},
    ref="flash_attention", example=_example, key_kwargs=("causal",))
@functools.partial(jax.jit, static_argnames=("cfg", "causal"))
def flash_attention(q, k, v, causal: bool = True,
                    cfg: TroopConfig = TroopConfig()):
    """q (B,T,H,hd), k/v (B,S,KV,hd) -> (B,T,H,hd)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    bq = max(min(128 * cfg.unroll, T), 1)
    while T % bq:
        bq //= 2
    bs = max(min(cfg.block_k // 2, S), 1)
    while S % bs:
        bs //= 2
    qg = q.reshape(B, T, KV, G, hd)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bs=bs, causal=causal),
        grid=(B, T // bq, S // bs),
        in_specs=[
            pl.BlockSpec((1, bq, KV, G, hd), lambda b, i, j: (b, i, 0, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, i, j: (b, j, 0, 0)),
            pl.BlockSpec((1, bs, KV, hd), lambda b, i, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, KV, G, hd),
                               lambda b, i, j: (b, i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, KV, G, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((KV, bq, G, 1), jnp.float32),
                        pltpu.VMEM((KV, bq, G, 1), jnp.float32),
                        pltpu.VMEM((KV, bq, G, hd), jnp.float32)],
        interpret=cfg.interpret,
    )(qg, k, v)
    return out.reshape(B, T, H, hd)
