"""Straggler / hang detection: per-step wall-time EMA watchdog.

On real pods, a straggling host shows up as a slow step on every host (SPMD
lockstep).  The watchdog flags steps slower than ``threshold x EMA`` and
escalates after ``patience`` consecutive flags — the trainer responds by
checkpoint-and-restart (which re-schedules around the sick host) per
standard practice.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class StepWatchdog:
    threshold: float = 3.0
    patience: int = 3
    decay: float = 0.9
    warmup: int = 5

    ema: Optional[float] = None
    seen: int = 0
    consecutive: int = 0
    events: List[dict] = field(default_factory=list)
    _t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        """Record step time; returns True when escalation is warranted."""
        dt = time.perf_counter() - self._t0
        return self.record(step, dt)

    def record(self, step: int, dt: float) -> bool:
        self.seen += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_slow = self.seen > self.warmup and dt > self.threshold * self.ema
        if is_slow:
            self.consecutive += 1
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            self.consecutive = 0
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return self.consecutive >= self.patience
