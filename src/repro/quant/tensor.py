"""QuantizedTensor — the repo's one quantized-array representation.

TROOP's completion criterion is ``runtime == bytes / BW``: on a low-OI
kernel every operand byte IS the bound, so shrinking operand bytes moves
the roofline itself (PAPER §II; "Know your rooflines!", PAPERS.md).  This
module is the primitive layer every quantized path shares:

  * ``QuantizedTensor`` — a pytree of int8 storage (int4 packs two values
    per byte along the grouped axis) + per-group absmax scales.  The
    group size is a multiple of the ``core.troop`` layout granule for
    int8 storage, so scale blocks tile exactly with the mechanism-D
    hardware granules the kernels block on (one scale block per
    (block_n, group) tile — no scale fetch ever straddles a tile edge).
  * ``quantize`` / ``dequantize`` — absmax calibration and its inverse,
    grouped along one (reduction) axis or per-tensor.
  * ``pack_int4`` / ``unpack_int4`` — nibble packing used by the int4
    kernels (low nibble = even index, high nibble = odd index).

Consumers: ``repro.quant.params`` (weight pytrees), ``repro.quant.kernels``
(fused-dequant qgemv), ``models/attention.py`` (quantized KV),
``serve/kvcache.py`` (int8 page pools), ``dist/compression.py`` (gradient
compression).  Kept import-light (jax + core.troop only): models and the
serving layer import it at module scope.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.troop import sublane

# storage is always int8; int4 packs two values per byte
STORAGE_DTYPE = jnp.int8


def granule() -> int:
    """Layout granule (rows) of the int8 storage dtype — scale groups must
    tile in multiples of this so scale blocks align with mechanism-D tiles."""
    return sublane(STORAGE_DTYPE)


def _qmax(bits: int) -> int:
    assert bits in (8, 4), f"bits must be 8 or 4, got {bits}"
    return 127 if bits == 8 else 7


# --------------------------------------------------------------------------
# MX microscaling (OCP): shared-exponent block formats
# --------------------------------------------------------------------------
# Per-block uint8 E8M0 scale (a biased power of two; bias 127) shared by a
# block of ``granule()`` low-precision elements along the grouped axis:
#   mx4: fp4 e2m1 element codes, two per byte (magnitudes 0..6)
#   fp8: float8_e4m3fn elements (magnitudes 0..448)
# The scale block equals the int8 layout granule (32 rows), so one E8M0
# byte rides with exactly one mechanism-D tile row-group in the kernels.
E8M0_BIAS = 127
_MX_EMAX = {"fp4": 2, "fp8": 8}          # floor(log2(max finite element))
_FP4_MAX = 6.0
_FP8_MAX = 448.0
FP8_DTYPE = jnp.float8_e4m3fn
# e2m1 magnitude midpoints: digitize(|v|) -> magnitude code 0..7
_FP4_MIDPOINTS = (0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0)


def fp4_encode(x):
    """float -> uint8 e2m1 codes (bit3 sign, bits2:1 exp, bit0 mantissa).
    Magnitudes saturate at 6.0 (round-to-nearest over the 8-entry table)."""
    mag = jnp.digitize(jnp.abs(x.astype(jnp.float32)),
                       jnp.asarray(_FP4_MIDPOINTS, jnp.float32))
    sign = jnp.where(x < 0, 8, 0)
    return (mag + sign).astype(jnp.uint8)


def fp4_decode(codes, dtype=jnp.float32):
    """uint8 e2m1 codes -> float: sign * (exp==0 ? 0.5*man
    : (1+0.5*man)*2^(exp-1)).  Branch-free, usable inside Pallas kernels."""
    c = codes.astype(jnp.int32)
    sign = 1.0 - 2.0 * (c >> 3).astype(jnp.float32)
    exp = ((c >> 1) & 3).astype(jnp.float32)
    man = (c & 1).astype(jnp.float32)
    mag = jnp.where(exp == 0, 0.5 * man,
                    (1.0 + 0.5 * man) * jnp.exp2(exp - 1.0))
    return (sign * mag).astype(dtype)


def pack_fp4(codes, axis: int = -1):
    """Pack uint8 e2m1 codes two-per-byte along ``axis`` (even -> low
    nibble, odd -> high).  Extent must be even."""
    ax = axis if axis < 0 else axis - codes.ndim
    cm = jnp.moveaxis(codes, ax, -1)
    K = cm.shape[-1]
    assert K % 2 == 0, f"fp4 packing needs an even extent, got {K}"
    pairs = cm.reshape(cm.shape[:-1] + (K // 2, 2)).astype(jnp.uint8)
    packed = pairs[..., 0] | jnp.left_shift(pairs[..., 1], 4)
    return jnp.moveaxis(packed.astype(jnp.uint8), -1, ax)


def unpack_fp4(packed, axis: int = -1):
    """Inverse of ``pack_fp4``: (..., K//2) uint8 -> (..., K) uint8 codes."""
    ax = axis if axis < 0 else axis - packed.ndim
    pm = jnp.moveaxis(packed, ax, -1).astype(jnp.uint8)
    lo = pm & jnp.uint8(0x0F)
    hi = jnp.right_shift(pm, 4)
    out = jnp.stack([lo, hi], axis=-1).reshape(pm.shape[:-1]
                                               + (2 * pm.shape[-1],))
    return jnp.moveaxis(out.astype(jnp.uint8), -1, ax)


def e8m0_decode(scales, dtype=jnp.float32):
    """uint8 E8M0 biased exponents -> power-of-two scale factors."""
    return jnp.exp2(scales.astype(jnp.float32) - E8M0_BIAS).astype(dtype)


# --------------------------------------------------------------------------
# int4 nibble packing
# --------------------------------------------------------------------------
def pack_int4(q, axis: int = -1):
    """Pack int8-held int4 values (range [-7, 7]) two-per-byte along
    ``axis`` (even index -> low nibble, odd -> high).  Extent must be even."""
    ax = axis if axis < 0 else axis - q.ndim
    qm = jnp.moveaxis(q, ax, -1)
    K = qm.shape[-1]
    assert K % 2 == 0, f"int4 packing needs an even extent, got {K}"
    pairs = qm.reshape(qm.shape[:-1] + (K // 2, 2))
    lo = pairs[..., 0] & jnp.int8(0x0F)
    hi = jnp.left_shift(pairs[..., 1], 4)          # wraps mod 256: the nibble
    return jnp.moveaxis((lo | hi).astype(jnp.int8), -1, ax)


def unpack_int4(packed, axis: int = -1):
    """Inverse of ``pack_int4``: (..., K//2) int8 -> (..., K) int8 values."""
    ax = axis if axis < 0 else axis - packed.ndim
    pm = jnp.moveaxis(packed, ax, -1)
    lo = jnp.right_shift(jnp.left_shift(pm, 4), 4)  # arithmetic: sign-extend
    hi = jnp.right_shift(pm, 4)
    out = jnp.stack([lo, hi], axis=-1).reshape(pm.shape[:-1]
                                               + (2 * pm.shape[-1],))
    return jnp.moveaxis(out.astype(jnp.int8), -1, ax)


# --------------------------------------------------------------------------
# QuantizedTensor pytree
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """Quantized values + per-group scales (absmax or MX block-exponent).

    ``values``/``scales`` are the pytree children (they trace, scan-slice
    and shard like any array); ``bits``/``group_size``/``axis``/``fmt`` are
    static.  ``axis`` is stored NEGATIVE so slicing leading dims
    (``lax.scan`` over stacked layer groups) keeps it valid.  ``axis=None``
    means one per-tensor scalar scale (the gradient-compression layout).

    ``fmt`` selects the element/scale encoding:
      * ``"int"`` — int8/int4 values, float absmax scales (the default)
      * ``"mx"``  — MX microscaling: uint8 E8M0 block exponents; values are
        packed fp4 e2m1 codes (uint8, ``bits=4``) or float8_e4m3fn
        (``bits=8``) — discriminated by ``values.dtype``.
    """
    values: Any                      # int8 storage; int4/fp4: packed on axis
    scales: Any                      # (..., extent // group_size) or scalar
    bits: int = 8
    group_size: int = 0              # effective group (0 for per-tensor)
    axis: Optional[int] = -1         # grouped axis (negative), None = tensor
    fmt: str = "int"                 # "int" (absmax) | "mx" (block exponent)

    def tree_flatten(self):
        return ((self.values, self.scales),
                (self.bits, self.group_size, self.axis, self.fmt))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    # ------------------------------------------------------------- views
    @property
    def shape(self):
        """Logical (unpacked) shape."""
        s = list(self.values.shape)
        if self.bits == 4 and self.axis is not None:
            s[self.axis] = s[self.axis] * 2
        return tuple(s)

    @property
    def nbytes(self) -> int:
        n = int(math.prod(self.values.shape))
        m = int(math.prod(getattr(self.scales, "shape", ())))
        return n + m * jnp.dtype(self.scales.dtype).itemsize

    def dequantize(self, dtype=jnp.float32):
        return dequantize(self, dtype)


def absmax_scales(x, *, bits: int = 8, group_size: Optional[int] = None,
                  axis: Optional[int] = -1, eps: float = 1e-8):
    """Absmax calibration: per-group max(|x|)/qmax (floored at ``eps``).

    ``axis=None`` -> one scalar scale; otherwise groups of ``group_size``
    along ``axis`` (``None``/non-dividing group sizes collapse to one group
    spanning the whole axis).  Returns (scales, effective_group_size).
    """
    xf = jnp.abs(x.astype(jnp.float32))
    q = _qmax(bits)
    if axis is None:
        return jnp.maximum(jnp.max(xf) / q, eps), 0
    ax = axis if axis < 0 else axis - x.ndim
    K = x.shape[ax]
    g = group_size or K
    if K % g:
        g = K                              # fallback: one group per row
    xm = jnp.moveaxis(xf, ax, -1)
    amax = jnp.max(xm.reshape(xm.shape[:-1] + (K // g, g)), axis=-1)
    scales = jnp.maximum(amax / q, eps)
    return jnp.moveaxis(scales, -1, ax), g


def quantize(x, *, bits: int = 8, group_size: Optional[int] = None,
             axis: Optional[int] = -1, eps: float = 1e-8,
             scale_dtype=jnp.float32) -> QuantizedTensor:
    """Absmax-quantize ``x`` to a ``QuantizedTensor``.

    int8 clips to [-127, 127]; int4 to [-7, 7] and packs two values per
    byte along ``axis`` (extent must be even for int4).
    """
    qmax = _qmax(bits)
    scales, g = absmax_scales(x, bits=bits, group_size=group_size,
                              axis=axis, eps=eps)
    xf = x.astype(jnp.float32)
    if axis is None:
        q = jnp.clip(jnp.round(xf / scales), -qmax, qmax).astype(STORAGE_DTYPE)
        return QuantizedTensor(q, scales.astype(scale_dtype), bits, 0, None)
    ax = axis if axis < 0 else axis - x.ndim
    K = x.shape[ax]
    xm = jnp.moveaxis(xf, ax, -1)
    sm = jnp.moveaxis(scales, ax, -1)
    q = xm.reshape(xm.shape[:-1] + (K // g, g)) / sm[..., None]
    q = jnp.clip(jnp.round(q), -qmax, qmax).astype(STORAGE_DTYPE)
    q = jnp.moveaxis(q.reshape(xm.shape), -1, ax)
    if bits == 4:
        q = pack_int4(q, axis=ax)
    return QuantizedTensor(q, jnp.moveaxis(sm, -1, ax).astype(scale_dtype),
                           bits, g, ax)


def quantize_mx(x, *, elem: str = "fp4", axis: Optional[int] = -2,
                block: Optional[int] = None) -> QuantizedTensor:
    """MX-quantize ``x``: per-block shared exponent (uint8 E8M0) + fp4
    (e2m1, packed two-per-byte) or fp8 (e4m3) element codes.

    The block size defaults to the TROOP int8 layout granule (32) so each
    E8M0 byte covers exactly one mechanism-D tile row-group; a
    non-dividing extent collapses to one block per row (mirroring
    ``absmax_scales``).  ``elem="fp4"`` falls back to fp8 when the grouped
    extent is odd (cannot nibble-pack).  ``axis`` defaults to -2: weights
    are stored (in_dim, out_dim) and the kernels reduce over rows.
    """
    assert elem in ("fp4", "fp8"), f"elem must be fp4|fp8, got {elem}"
    assert axis is not None, "MX needs a grouped axis"
    ax = axis if axis < 0 else axis - x.ndim
    K = x.shape[ax]
    g = block or granule()
    if K % g:
        g = K                              # fallback: one block per row
    if elem == "fp4" and K % 2:
        elem = "fp8"                       # odd extent cannot nibble-pack
    emax = _MX_EMAX[elem]
    xm = jnp.moveaxis(x.astype(jnp.float32), ax, -1)
    blocks = xm.reshape(xm.shape[:-1] + (K // g, g))
    amax = jnp.max(jnp.abs(blocks), axis=-1)
    # shared exponent: floor(log2(amax)) - emax_elem, biased into E8M0
    e = jnp.where(amax > 0.0,
                  jnp.floor(jnp.log2(jnp.maximum(amax, 1e-38))) - emax,
                  jnp.float32(-E8M0_BIAS))
    e = jnp.clip(e, -E8M0_BIAS, E8M0_BIAS)
    scales = (e + E8M0_BIAS).astype(jnp.uint8)
    scaled = blocks * jnp.exp2(-e)[..., None]
    if elem == "fp4":
        codes = fp4_encode(jnp.clip(scaled, -_FP4_MAX, _FP4_MAX))
        v = pack_fp4(codes.reshape(xm.shape), axis=-1)
        bits = 4
    else:
        v = jnp.clip(scaled, -_FP8_MAX, _FP8_MAX).reshape(
            xm.shape).astype(FP8_DTYPE)
        bits = 8
    return QuantizedTensor(jnp.moveaxis(v, -1, ax),
                           jnp.moveaxis(scales, -1, ax), bits, g, ax, "mx")


def dequantize(qt: QuantizedTensor, dtype=jnp.float32):
    """Inverse of ``quantize`` (up to rounding): values * per-group scale."""
    v = qt.values
    if qt.axis is None:
        return (v.astype(jnp.float32)
                * qt.scales.astype(jnp.float32)).astype(dtype)
    ax = qt.axis
    if qt.fmt == "mx":
        v = fp4_decode(unpack_fp4(v, axis=ax)) if qt.bits == 4 \
            else v.astype(jnp.float32)
        sm = e8m0_decode(jnp.moveaxis(qt.scales, ax, -1))
    else:
        if qt.bits == 4:
            v = unpack_int4(v, axis=ax)
        sm = jnp.moveaxis(qt.scales, ax, -1).astype(jnp.float32)
    vm = jnp.moveaxis(v, ax, -1).astype(jnp.float32)
    K = vm.shape[-1]
    g = K // sm.shape[-1]
    out = (vm.reshape(vm.shape[:-1] + (sm.shape[-1], g))
           * sm[..., None]).reshape(vm.shape)
    return jnp.moveaxis(out, -1, ax).astype(dtype)


def dequantize_values(values, scales, *, axis: int = -1, bits: int = 8,
                      fmt: str = "int", dtype=jnp.float32):
    """Raw (values, scales) dequant — the oracle form used by kernel refs
    and cache paths that carry the two arrays separately."""
    g = 0
    if axis is not None:
        ext = values.shape[axis] * (2 if bits == 4 else 1)
        g = ext // scales.shape[axis] if scales.ndim == values.ndim else ext
    return dequantize(QuantizedTensor(values, scales, bits, g, axis, fmt),
                      dtype)


# --------------------------------------------------------------------------
# The repo's two historical int8 layouts, as thin views over quantize()
# --------------------------------------------------------------------------
def quantize_kv(x, scale_dtype=jnp.bfloat16):
    """KV layout: (..., hd) -> int8 values + per-row scale (..., 1).

    One absmax group spanning the head dim: the scale rides next to its
    token in the cache / page pool (§Perf A4 layout; ``models/attention``
    and the int8 page pools both use exactly this form).
    """
    qt = quantize(x, bits=8, group_size=None, axis=-1, eps=1e-8,
                  scale_dtype=scale_dtype)
    return qt.values, qt.scales


def dequantize_kv(q, scale, dtype):
    return q.astype(dtype) * scale.astype(dtype)


def quantize_int8(x):
    """Gradient-compression layout: (int8 values, fp32 scalar scale)
    (``dist/compression`` semantics: scale = max(|x|, 1e-12) / 127)."""
    qt = quantize(x, bits=8, axis=None, eps=1e-12 / 127.0)
    return qt.values, qt.scales


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale
