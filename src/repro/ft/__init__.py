from repro.ft.failures import FailureInjector, SimulatedFailure
from repro.ft.watchdog import StepWatchdog
from repro.ft.elastic import elastic_meshes, reshard_tree

__all__ = ["FailureInjector", "SimulatedFailure", "StepWatchdog",
           "elastic_meshes", "reshard_tree"]
