"""qwen2-moe-a2.7b — 60 routed top-4 + 4 shared experts (gated).

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H d_ff(moe)=1408
vocab=151936.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, num_experts_per_tok=4, d_ff=1408,
                  num_shared_experts=4, shared_d_ff=5632,
                  shared_expert_gate=True, norm_topk_prob=True),
    rope_theta=1_000_000.0,
)
