"""Production mesh builders (functions — importing never touches devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host offers (tests / smoke runs)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
