import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def tree(key, scale=1.0):
    ks = jax.random.split(key, 3)
    return {"a": scale * jax.random.normal(ks[0], (16, 8)),
            "b": {"w": scale * jax.random.normal(ks[1], (32,)),
                  "s": jnp.zeros((), jnp.int32)}}


def test_roundtrip_identity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = tree(jax.random.PRNGKey(0))
    mgr.save(7, t, extra={"data_step": 7})
    got, extra = mgr.restore(t)
    assert extra["data_step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_keep_k_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = tree(jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = tree(jax.random.PRNGKey(1), scale=2.0)
    mgr.save(10, t)
    mgr.wait()
    got, _ = mgr.restore(t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_restore_rejects_mismatched_tree(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, tree(jax.random.PRNGKey(0)))
    bad = {"a": jnp.zeros((16, 8)), "c": jnp.zeros((4,))}
    with pytest.raises(AssertionError):
        mgr.restore(bad)


def test_atomicity_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, tree(jax.random.PRNGKey(0)))
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
