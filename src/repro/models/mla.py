"""Multi-head Latent Attention (DeepSeek-V2), with absorbed decode.

Decode caches the *compressed* latent (kv_lora + rope dims) — exactly the
paper's low-operational-intensity GEMV workload: per decoded token the score
and value contractions stream the latent cache once with O(1) reuse.

Two decode modes:
  * ``expand``  — up-project all cached latents each step (naive).
  * ``absorb``  — fold W_UK into the query and W_UV into the output
    projection so the per-step work is a GEMV against the latent cache
    (production mode; also the §Perf hillclimb subject).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partitioning as PT
from repro.models import modules as M


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, kv_lora)
    k_pe: jax.Array    # (B, S, rope_dim)


def mla_init(key, cfg):
    m, d, H = cfg.mla, cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": M.dense_init(ks[0], d, H * qd, ("embed", "qkv_out")),
        "wdkv": M.dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim,
                             ("embed", None)),
        "kv_norm": M.norm_init("rmsnorm", m.kv_lora_rank, (None,)),
        "wuk": M.dense_init(ks[2], m.kv_lora_rank, H * m.qk_nope_head_dim,
                            ("kv_lora", "qkv_out")),
        "wuv": M.dense_init(ks[3], m.kv_lora_rank, H * m.v_head_dim,
                            ("kv_lora", "qkv_out")),
        "wo": M.dense_init(ks[4], H * m.v_head_dim, d, ("qkv_out", "embed")),
    }


def _queries(p, cfg, x, positions, dtype):
    m, H = cfg.mla, cfg.num_heads
    B, T = x.shape[:2]
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = M.apply_dense(p["wq"], x, dtype).reshape(B, T, H, qd)
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = M.apply_rope(q_pe, positions, cfg.rope_theta)
    hax = ("batch", None, "heads", None)
    return PT.constrain(q_nope, hax), PT.constrain(q_pe, hax)


def _latent(p, cfg, x, positions, dtype):
    m = cfg.mla
    ckv = M.apply_dense(p["wdkv"], x, dtype)
    c_kv, k_pe = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c_kv = M.apply_norm(p["kv_norm"], c_kv, "rmsnorm", cfg.norm_eps)
    k_pe = M.apply_rope(k_pe[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_pe


def apply_mla(p, cfg, x, *, positions, dtype):
    """Full-sequence (train / prefill): expand latents to per-head K/V."""
    m, H = cfg.mla, cfg.num_heads
    B, T = x.shape[:2]
    q_nope, q_pe = _queries(p, cfg, x, positions, dtype)
    c_kv, k_pe = _latent(p, cfg, x, positions, dtype)
    hax = ("batch", None, "heads", None)
    k_nope = PT.constrain(M.apply_dense(p["wuk"], c_kv, dtype).reshape(
        B, T, H, m.qk_nope_head_dim), hax)
    v = PT.constrain(M.apply_dense(p["wuv"], c_kv, dtype)
                     .reshape(B, T, H, m.v_head_dim), hax)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bthi,bshi->bhts", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bthi,bsi->bhts", q_pe, k_pe,
                           preferred_element_type=jnp.float32)) * scale
    scores = PT.constrain(scores, ("batch", "heads", None, None))
    tpos = jnp.arange(T)
    mask = tpos[None, None, :, None] < tpos[None, None, None, :]
    scores = jnp.where(mask, jnp.finfo(jnp.float32).min, scores)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    # §Perf C3: pin probs/out shardings (same GSPMD involuntary-remat class
    # of failure that B2 fixed in the GQA path).
    probs = PT.constrain(probs, ("batch", "heads", None, None))
    out = jnp.einsum("bhts,bshi->bthi", probs, v)
    out = PT.constrain(out, ("batch", None, "heads", None)).reshape(B, T, -1)
    return M.apply_dense(p["wo"], out, dtype)


def apply_mla_decode(p, cfg, x, cache: MLACache, pos, dtype, mode="absorb"):
    m, H = cfg.mla, cfg.num_heads
    B = x.shape[0]
    S = cache.c_kv.shape[1]
    q_nope, q_pe = _queries(p, cfg, x, pos[:, None], dtype)
    c_new, kpe_new = _latent(p, cfg, x, pos[:, None], dtype)
    from repro.models.attention import update_cache
    c_kv = update_cache(cache.c_kv, c_new, pos)      # O(1)-byte scatter (A1)
    k_pe = update_cache(cache.k_pe, kpe_new, pos)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    c_kv = PT.constrain(c_kv, ("batch", "cache_seq", None))
    k_pe = PT.constrain(k_pe, ("batch", "cache_seq", None))
    if mode == "absorb":
        # q' = q_nope @ W_UK^T : (B,1,H,kv_lora) — scores are a GEMV on the
        # compressed cache; the attention output stays in latent space and is
        # up-projected once (W_UV) for the single query token.
        wuk = p["wuk"]["w"].astype(dtype).reshape(
            m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bthi,chi->bthc", q_nope, wuk,
                           preferred_element_type=jnp.float32).astype(dtype)
        # A3: contract the latent cache in its own dtype (no fp32 copies of
        # the cache); fp32 is only the accumulator (MXU semantics) and the
        # small scores for the softmax.
        scores = (jnp.einsum("bthc,bsc->bhts", q_lat.astype(c_kv.dtype),
                             c_kv, preferred_element_type=jnp.float32)
                  + jnp.einsum("bthi,bsi->bhts", q_pe.astype(k_pe.dtype),
                               k_pe,
                               preferred_element_type=jnp.float32)) * scale
        scores = PT.constrain(scores,
                              ("batch", None, None, "attn_kv_seq"))
    else:
        k_nope = M.apply_dense(p["wuk"], c_kv, dtype).reshape(
            B, S, H, m.qk_nope_head_dim)
        scores = (jnp.einsum("bthi,bshi->bhts", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bthi,bsi->bhts", q_pe, k_pe,
                               preferred_element_type=jnp.float32)) * scale

    smask = jnp.arange(S)[None, None, None, :] > pos[:, None, None, None]
    scores = jnp.where(smask, jnp.finfo(jnp.float32).min, scores)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)

    if mode == "absorb":
        out_lat = jnp.einsum("bhts,bsc->bthc", probs, c_kv,
                             preferred_element_type=jnp.float32)
        wuv = p["wuv"]["w"].astype(dtype).reshape(
            m.kv_lora_rank, H, m.v_head_dim)
        out = jnp.einsum("bthc,chi->bthi", out_lat.astype(dtype), wuv,
                         preferred_element_type=jnp.float32).astype(dtype)
    else:
        v = M.apply_dense(p["wuv"], c_kv, dtype).reshape(
            B, S, H, m.v_head_dim)
        out = jnp.einsum("bhts,bshi->bthi", probs, v)
    out = M.apply_dense(p["wo"], out.reshape(B, 1, -1), dtype)
    return out, MLACache(c_kv, k_pe)


def init_mla_cache(cfg, B: int, S: int, dtype) -> MLACache:
    m = cfg.mla
    return MLACache(jnp.zeros((B, S, m.kv_lora_rank), dtype),
                    jnp.zeros((B, S, m.qk_rope_head_dim), dtype))
