"""Mamba selective-scan kernel — AXPY-class recurrence, one HBM pass.

h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D * x_t

The XLA reference is a T-step ``lax.scan``: every step re-touches HBM-level
buffers.  The kernel streams (x, dt, B, C) tiles once ((A)/(B): pipelined
dual-purpose fetches), keeps the (d_block, d_state) state in VMEM scratch
across the whole sequence ((C): shadow-state, committed only as y tiles),
and runs the recurrence on-chip.  Channels are independent, so the grid
parallelizes (batch x channel-block) like the paper's per-lane FPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, numel, troop_kernel


def _example(small: bool = True):
    b, T, di, ds = (1, 64, 128, 16) if small else (2, 512, 512, 16)
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, T, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, di)))
    B = jax.random.normal(ks[2], (b, T, ds))
    C = jax.random.normal(ks[3], (b, T, ds))
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)))
    D = jnp.ones((di,))
    s0 = jnp.zeros((b, di, ds))
    return (x, dt, B, C, A, D, s0), {}


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, o_ref, so_ref,
            state, *, bt):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)          # (bt, dc)
    dt = dt_ref[0].astype(jnp.float32)        # (bt, dc)
    B = b_ref[0].astype(jnp.float32)          # (bt, ds)
    C = c_ref[0].astype(jnp.float32)          # (bt, ds)
    A = a_ref[0].astype(jnp.float32)          # (dc, ds), A < 0
    D = d_ref[0].astype(jnp.float32)          # (1, dc)

    def step(t, carry):
        h, ys = carry
        a_t = jnp.exp(dt[t][:, None] * A)                 # exp(<=0): safe
        h = a_t * h + (dt[t] * x[t])[:, None] * B[t][None, :]
        y_t = jnp.sum(h * C[t][None, :], axis=-1)         # (dc,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    h0 = state[...]
    ys = jnp.zeros((bt, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, bt, step, (h0, ys))
    state[...] = h
    o_ref[0] = (ys + x * D).astype(o_ref.dtype)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        so_ref[0] = state[...]


@troop_kernel(
    "mamba_scan",
    # per (t, channel): state decay + update + output contraction over ds
    flops=lambda x, dt, B, C, A, D, s0: (6.0 * numel(x) * A.shape[1]),
    bytes=lambda x, dt, B, C, A, D, s0: (
        (2 * numel(x) + numel(B) + numel(C)) * itemsize(x)
        + numel(x) * itemsize(x)            # y out
        + (numel(A) + numel(D) + numel(s0)) * 4),
    streamed=lambda x, dt, B, C, A, D, s0: [
        x, jax.ShapeDtypeStruct(dt.shape, x.dtype),      # x, dt in
        jax.ShapeDtypeStruct(B.shape, x.dtype),
        jax.ShapeDtypeStruct(C.shape, x.dtype),
        x,                                               # y out (x-shaped)
        jax.ShapeDtypeStruct(A.shape, jnp.float32),
        jax.ShapeDtypeStruct(D.shape, jnp.float32),
        jax.ShapeDtypeStruct(s0.shape, jnp.float32)],
    space={"block_n": (64, 128, 256)},
    ref="mamba_scan", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def mamba_scan(x, dt, B, C, A, D, state0, cfg: TroopConfig = TroopConfig()):
    """x, dt: (b, T, di); B, C: (b, T, ds); A: (di, ds) (<0); D: (di,);
    state0: (b, di, ds) fp32 (must be zeros — prefill form).

    Returns (y (b, T, di) f32, state (b, di, ds) f32)."""
    b, T, di = x.shape
    ds = B.shape[-1]
    dc = min(256, di)
    while di % dc:
        dc //= 2
    bt = max(min(cfg.block_n // 8, T), 1)
    while T % bt:
        bt //= 2
    nch = di // dc

    # fold channel blocks into the outer grid dim alongside batch
    def fold(t):   # (b, T, di) -> (b * nch, T, dc)
        return (t.reshape(b, T, nch, dc).transpose(0, 2, 1, 3)
                .reshape(b * nch, T, dc))
    xf, dtf = fold(x), fold(dt)
    Bf = jnp.repeat(B, nch, axis=0) if nch > 1 else B
    Cf = jnp.repeat(C, nch, axis=0) if nch > 1 else C
    Af = A.reshape(nch, dc, ds)
    Df = D.reshape(nch, 1, dc)

    y, state = pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(b * nch, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, dc), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, bt, dc), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, bt, ds), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, bt, ds), lambda g, j: (g, j, 0)),
            pl.BlockSpec((1, dc, ds), lambda g, j, n=nch: (g % n, 0, 0)),
            pl.BlockSpec((1, 1, dc), lambda g, j, n=nch: (g % n, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, bt, dc), lambda g, j: (g, j, 0)),
                   pl.BlockSpec((1, dc, ds), lambda g, j: (g, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b * nch, T, dc), jnp.float32),
                   jax.ShapeDtypeStruct((b * nch, dc, ds), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((dc, ds), jnp.float32)],
        interpret=cfg.interpret,
    )(xf, dtf, Bf, Cf, Af.reshape(nch, dc, ds), Df)

    y = (y.reshape(b, nch, T, dc).transpose(0, 2, 1, 3).reshape(b, T, di))
    state = state.reshape(b, nch, dc, ds).reshape(b, di, ds)
    return y, state
