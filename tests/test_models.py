"""Per-arch smoke tests + decode/train consistency (teacher forcing)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M

B, T = 2, 16


def make(arch, capacity_factor=None, dtype=None, **rt_over):
    import dataclasses
    cfg = reduced(get_config(arch), **({"dtype": dtype} if dtype else {}))
    if capacity_factor and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=capacity_factor))
    rt = RuntimeConfig(remat="none", moe_groups=1, **rt_over)
    model = build_model(cfg, rt)
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def batch_for(cfg, T):
    tok_len = T - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    batch = {"tokens": jnp.arange(B * tok_len).reshape(B, tok_len) % 7 + 1}
    if cfg.frontend == "vision":
        batch["frontend"] = 0.1 * jnp.ones(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_decoder:
        batch["frontend"] = 0.1 * jnp.ones(
            (B, cfg.cross_attention_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_prefill_decode(arch):
    cfg, model, params = make(arch)
    batch = batch_for(cfg, T)
    logits, aux = model.train_logits(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not jnp.isnan(logits.astype(jnp.float32)).any()
    assert jnp.isfinite(jnp.asarray(aux))

    _, caches_p = model.prefill(params, batch)
    caches = model.init_caches(B, 32)
    step = {"tokens": jnp.ones((B, 1), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32)}
    lg, caches2 = model.decode_step(params, step, caches)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert not jnp.isnan(lg.astype(jnp.float32)).any()
    # cache structure preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("cache shape changed"), caches, caches2)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-3b", "glm4-9b",
                                  "deepseek-v2-lite-16b", "jamba-v0.1-52b"])
def test_decode_matches_teacher_forcing(arch):
    """Token-by-token decode with caches must reproduce full-seq logits.

    Capacity-based MoE drops depend on the routing-group token count, so the
    invariant only holds drop-free: use a large capacity factor here (serving
    configs do the same — see DESIGN.md).  fp32 activations: at bf16 the
    two paths' ~1e-3 reassociation noise can flip a near-tie MoE top-k pick
    (observed margin 6e-4 on deepseek), which is a property of routing
    discreteness, not of the cache logic under test — fp32 makes the
    invariant well-posed and lets the tolerance tighten 30x.
    """
    cfg, model, params = make(arch, capacity_factor=8.0, dtype="float32")
    if cfg.frontend == "vision":
        pytest.skip("prefix handling covered by smoke")
    Tt = 8
    toks = (jnp.arange(B * Tt).reshape(B, Tt) % 11) + 1
    full_logits, _ = model.train_logits(params, {"tokens": toks})

    caches = model.init_caches(B, Tt + 1)
    outs = []
    for t in range(Tt):
        step = {"tokens": toks[:, t:t + 1],
                "pos": jnp.full((B,), t, jnp.int32)}
        lg, caches = model.decode_step(params, step, caches)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_continuation():
    """prefill caches splice into decode exactly (qwen 0.5b reduced)."""
    cfg, model, params = make("qwen1.5-0.5b")
    Tp = 8
    toks = (jnp.arange(B * Tp).reshape(B, Tp) % 11) + 1
    logits_p, caches_p = model.prefill(params, {"tokens": toks})

    from repro.serve.scheduler import splice_cache
    caches = model.init_caches(B, Tp + 4)
    # splice per batch row (B=1 prefills)
    for b in range(B):
        one = jax.tree.map(lambda x: x[:, b:b + 1] if x.ndim > 1 and
                           x.shape[1] == B else x[b:b + 1], caches_p)
        caches = splice_cache(caches, one, b, B)
    nxt = jnp.argmax(logits_p[:, -1], -1)[:, None].astype(jnp.int32)
    lg, _ = model.decode_step(
        params, {"tokens": nxt, "pos": jnp.full((B,), Tp, jnp.int32)}, caches)

    # oracle: full forward over the extended sequence
    ext = jnp.concatenate([toks, nxt], axis=1)
    full, _ = model.train_logits(params, {"tokens": ext})
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=6e-2, atol=6e-2)


def test_moe_capacity_droppage_is_bounded():
    cfg, model, params = make("qwen2-moe-a2.7b")
    batch = batch_for(cfg, T)
    logits, aux = model.train_logits(params, batch)
    assert jnp.asarray(aux) < 1.0   # aux loss small for random router


def test_rwkv_long_state_is_o1():
    cfg, model, params = make("rwkv6-3b")
    c8 = model.init_caches(B, 8)
    c512 = model.init_caches(B, 512)
    s8 = sum(x.size for x in jax.tree.leaves(c8))
    s512 = sum(x.size for x in jax.tree.leaves(c512))
    assert s8 == s512   # attention-free: state independent of cache length


def test_int8_kv_cache_decode_close_to_bf16():
    """§Perf A4: quantized KV decode tracks the bf16 cache closely."""
    import dataclasses
    cfg = reduced(get_config("glm4-9b"))
    params = None
    res = {}
    for cache_dtype in ("bfloat16", "int8"):
        model = build_model(cfg, RuntimeConfig(remat="none",
                                               cache_dtype=cache_dtype))
        if params is None:
            params = M.unbox(model.init(jax.random.PRNGKey(0)))
        Tt = 6
        toks = (jnp.arange(B * Tt).reshape(B, Tt) % 11) + 1
        caches = model.init_caches(B, Tt + 1)
        outs = []
        for t in range(Tt):
            lg, caches = model.decode_step(
                params, {"tokens": toks[:, t:t + 1],
                         "pos": jnp.full((B,), t, jnp.int32)}, caches)
            outs.append(lg[:, 0])
        res[cache_dtype] = jnp.stack(outs, 1).astype(jnp.float32)
    err = float(jnp.max(jnp.abs(res["int8"] - res["bfloat16"])))
    assert err < 0.25, err
    # and the cache really is int8
    model = build_model(cfg, RuntimeConfig(cache_dtype="int8"))
    c = model.init_caches(B, 8)
    dtypes = {str(x.dtype) for x in jax.tree.leaves(c)}
    assert "int8" in dtypes
