"""Public kernel namespace — registry-dispatched entry points.

Importing this package loads every kernel module, which registers each
kernel into ``repro.tune.registry`` via ``@troop_kernel``.  The names
exported here are the dispatching wrappers: call one *with* an explicit
``TroopConfig`` and it behaves like the raw kernel; call it *without* one
and the best tuned config for (kernel, shapes, dtype, backend) is resolved
from the persistent tune cache (heuristic default on a miss).
"""
from repro.core.troop import BASELINE, TROOP, TroopConfig
from repro.kernels.ops import (axpy, batched_gemv, batched_mx_qgemv,
                               batched_qgemv, decode_attention,
                               decode_attention_int8,
                               decode_attention_stats, dotp, flash_attention,
                               fused_adamw, gemv, grouped_expert_qgemv,
                               lse_combine, mamba_scan, mx_qgemv,
                               mx_qgemv_swiglu, paged_decode_attention,
                               paged_decode_attention_int8,
                               prefill_attention_paged, qgemv, rmsnorm,
                               wkv6, wkv6_with_state)
from repro.tune.cache import get_tuned
from repro.tune.registry import REGISTRY

__all__ = ["gemv", "dotp", "axpy", "rmsnorm", "fused_adamw",
           "decode_attention", "decode_attention_stats",
           "decode_attention_int8", "paged_decode_attention",
           "paged_decode_attention_int8", "prefill_attention_paged",
           "qgemv", "batched_qgemv",
           "mx_qgemv", "batched_mx_qgemv", "mx_qgemv_swiglu",
           "grouped_expert_qgemv",
           "flash_attention",
           "wkv6", "wkv6_with_state", "mamba_scan", "batched_gemv",
           "lse_combine", "BASELINE", "TROOP", "TroopConfig",
           "get_tuned", "REGISTRY"]
