"""Speculative-decoding verification: host-side accept/reject rules.

TROOP frames decode as an OI~=1 workload pinned to the memory roofline;
speculation is the FLOP-side lever — the target model scores k draft
tokens plus one bonus position in a single weight pass, so every byte of
weights/KV streamed does up to (k+1)x useful work.  The functions here
implement the per-slot emission rule on the host (numpy), decoupled from
the batched jitted draft/verify forwards so they can be unit-tested
statistically (``tests/test_speculative.py``).

Two modes, two guarantees:

  * ``greedy_verify`` — temperature 0.  Accept draft tokens while they
    match the target argmax; emit the target argmax at the first mismatch
    (the "correction"), or the bonus-position argmax when every draft
    matched.  Every emitted token IS a target argmax conditioned on the
    previously emitted tokens — token-identical to non-speculative greedy
    decode by construction.
  * ``speculative_sample`` — temperature > 0.  Leviathan-style modified
    rejection sampling: accept draft token d with probability
    min(1, p_t(d) / p_d(d)); on rejection sample the correction from
    norm(max(p_t - p_d, 0)); when all k drafts are accepted, sample the
    bonus token from the target distribution at position k.  The marginal
    distribution of every emitted token equals the target distribution
    exactly (the standard proof: accepted mass + residual mass = p_t).

Both return ``(emitted, accepted)`` where ``emitted`` always contains
``accepted + 1`` tokens (the accepted drafts plus one correction/bonus
token) — a verify pass always produces at least one token, so speculation
never stalls even at acceptance 0.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def softmax(logits: np.ndarray, temperature: float = 1.0) -> np.ndarray:
    """Stable softmax over the last axis (float64 for exact host math)."""
    x = np.asarray(logits, np.float64) / max(temperature, 1e-8)
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def greedy_verify(target_argmax: Sequence[int],
                  draft_tokens: Sequence[int]) -> Tuple[List[int], int]:
    """Greedy acceptance: ``target_argmax`` has k+1 entries (row i is the
    target argmax after the i accepted drafts), ``draft_tokens`` has k."""
    emitted: List[int] = []
    for i, d in enumerate(draft_tokens):
        t = int(target_argmax[i])
        emitted.append(t)
        if t != int(d):
            return emitted, i
    emitted.append(int(target_argmax[len(draft_tokens)]))
    return emitted, len(draft_tokens)


def speculative_sample(target_probs: np.ndarray, draft_probs: np.ndarray,
                       draft_tokens: Sequence[int],
                       rng: np.random.Generator) -> Tuple[List[int], int]:
    """Modified rejection sampling over one verify window.

    ``target_probs``: (k+1, V) target distributions (row i conditions on
    the prompt + i accepted drafts); ``draft_probs``: (k, V) the draft
    distributions that proposed ``draft_tokens``.  Uses exactly one
    uniform draw per acceptance test and one categorical draw for the
    correction/bonus token from ``rng``.
    """
    k = len(draft_tokens)
    emitted: List[int] = []
    for i in range(k):
        d = int(draft_tokens[i])
        t_p = float(target_probs[i][d])
        d_p = float(draft_probs[i][d])
        if d_p <= 0.0 or rng.random() < min(1.0, t_p / d_p):
            emitted.append(d)
            continue
        resid = np.maximum(np.asarray(target_probs[i], np.float64)
                           - np.asarray(draft_probs[i], np.float64), 0.0)
        z = resid.sum()
        if z <= 0.0:                       # degenerate: p_t <= p_d pointwise
            resid = np.asarray(target_probs[i], np.float64)
            z = resid.sum()
        emitted.append(int(rng.choice(resid.shape[0], p=resid / z)))
        return emitted, i
    bonus = np.asarray(target_probs[k], np.float64)
    emitted.append(int(rng.choice(bonus.shape[0], p=bonus / bonus.sum())))
    return emitted, k
