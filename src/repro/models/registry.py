"""Unified model API + ``input_specs`` (ShapeDtypeStruct stand-ins).

``build_model(cfg)`` returns a ``Model`` facade with init / train_logits /
prefill / decode_step / init_caches, dispatching to the decoder-only or
encoder-decoder assembly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.transformer import RuntimeConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    rt: RuntimeConfig

    def init(self, key):
        if self.cfg.encoder_decoder:
            return ED.init_encdec(key, self.cfg)
        return T.init_lm(key, self.cfg)

    def train_logits(self, params, batch):
        fn = ED.train_logits if self.cfg.encoder_decoder else T.train_logits
        return fn(params, self.cfg, self.rt, batch)

    def prefill(self, params, batch):
        fn = ED.prefill if self.cfg.encoder_decoder else T.prefill
        return fn(params, self.cfg, self.rt, batch)

    def decode_step(self, params, batch, caches):
        return T.decode_step(params, self.cfg, self.rt, batch, caches)

    def chunk_step(self, params, batch, caches):
        """One chunked-prefill slab (see transformer.chunk_prefill_step)."""
        return T.chunk_prefill_step(params, self.cfg, self.rt, batch, caches)

    def verify_step(self, params, batch, caches):
        """Speculative verify slab: all-row logits (see
        transformer.verify_step)."""
        return T.verify_step(params, self.cfg, self.rt, batch, caches)

    def init_caches(self, B, S, dtype=None, page_spec=None,
                    chunk_stage: int = 0):
        """Decode caches; ``page_spec`` (serve.kvcache.PageSpec) switches
        plain attention KV leaves to the shared paged layout;
        ``chunk_stage`` > 0 (a chunk size) adds the one-slot bf16 staging
        buffer used by chunked prefill over int8 pools."""
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        return T.init_caches(self.cfg, self.rt, B, S, dtype,
                             page_spec=page_spec, chunk_stage=chunk_stage)


def build_model(cfg, rt: RuntimeConfig = RuntimeConfig()) -> Model:
    return Model(cfg, rt)


# --------------------------------------------------------------------------
# input_specs: weak-type-correct ShapeDtypeStruct stand-ins, no allocation
# --------------------------------------------------------------------------
def input_specs(cfg, shape, rt: RuntimeConfig = RuntimeConfig()) -> Dict[str, Any]:
    """Stand-ins for every model input of an (arch x shape) cell.

    train/prefill: token batch (+ stub frontend embeds).
    decode: single-token batch + position + pre-allocated caches.
    """
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)

    def token_batch(T):
        batch = {"tokens": sds((B, T), jnp.int32)}
        if cfg.frontend == "vision":
            batch["frontend"] = sds((B, cfg.frontend_tokens, cfg.d_model), f32)
            batch["tokens"] = sds((B, T - cfg.frontend_tokens), jnp.int32)
        if cfg.encoder_decoder:
            batch["frontend"] = sds((B, cfg.cross_attention_len, cfg.d_model),
                                    f32)
        return batch

    if shape.kind == "train":
        batch = token_batch(S)
        batch["targets"] = sds(batch["tokens"].shape, jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        return {"batch": token_batch(S)}
    # decode: one new token against a cache of length S
    batch = {"tokens": sds((B, 1), jnp.int32), "pos": sds((B,), jnp.int32)}
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, rt, B, S, f32))
    return {"batch": batch, "caches": caches}
