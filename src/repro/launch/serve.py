"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Continuous-batching engine around the jitted prefill/decode steps (the
paper's decode workload): bucketed batched prefill (one compile per length
bucket), pluggable cache backend (``--backend paged`` is the default:
page-pool KV with block tables, see serve.kvcache).  ``--smoke`` uses the
reduced config on the host and prints the engine metrics.

``--tp N`` serves tensor-parallel over N devices (``repro.dist.tp``,
DESIGN.md §8); on a CPU host the launcher simulates the mesh by setting
``XLA_FLAGS=--xla_force_host_platform_device_count`` *before* JAX loads —
which is why every heavyweight import lives inside ``main``.
"""
import argparse
import json
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel devices: shard heads/ffn/experts "
                         "+ KV pools under shard_map (1 = single device)")
    ap.add_argument("--tp-mode", choices=("exact", "overlap"),
                    default="exact",
                    help="exact: token-identical to tp=1; overlap: ring "
                         "collective matmuls (communication hidden behind "
                         "the GEMV, tolerance-equal)")
    ap.add_argument("--sync-dispatch", action="store_true",
                    help="disable the async submit/stream-out pipeline "
                         "(decode consumed in the cycle it was submitted)")
    ap.add_argument("--backend", choices=("dense", "paged"), default="paged")
    ap.add_argument("--kernel-decode", action="store_true",
                    help="attend via the tuned Pallas paged kernel (no "
                         "gathered dense view; slow in CPU interpret mode)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="prefill as fixed-size token slabs interleaved "
                         "with decode (one compiled prefill shape, no "
                         "pow2 buckets; requires --backend paged, "
                         "attention-only archs)")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="tokens per prefill slab (--chunked-prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix KV pages across requests "
                         "(radix index + refcounts + copy-on-write; "
                         "requires --chunked-prefill)")
    ap.add_argument("--draft-arch", default=None, metavar="ID",
                    help="draft model for speculative decoding (a registry "
                         "arch id; reduced under --smoke like the target); "
                         "requires --speculate-k")
    ap.add_argument("--speculate-k", type=int, default=0,
                    help="speculative lookahead: draft K tokens per cycle "
                         "and verify K+1 positions in one target pass "
                         "(requires --draft-arch and --chunked-prefill; "
                         "greedy output is token-identical to K=0)")
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per page (default: the layout granule — "
                         "16 for bf16 pools, 32 for --kv-cache-dtype int8)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool pages per layer (default: full occupancy)")
    ap.add_argument("--quantize-weights",
                    choices=("none", "int8", "int4", "mx4", "fp8"),
                    default="none",
                    help="quantize matmul weights via repro.quant."
                         "quantize_params (MLP/attention projections; "
                         "embeddings/norms stay raw — DESIGN.md §5). "
                         "mx4/fp8 are the MX microscaling formats "
                         "(block-exponent E8M0 scales; MoE expert stacks "
                         "quantize too — DESIGN.md §11)")
    ap.add_argument("--quantize-group-size", type=int, default=128,
                    help="scale-group rows on the contraction axis (32-row "
                         "granule multiple; under --tp each weight shard "
                         "must hold whole groups — shrink for small archs)")
    ap.add_argument("--kv-cache-dtype", choices=("model", "int8"),
                    default="model",
                    help="int8: quantized KV (int8 page pools + scale "
                         "pages under --backend paged; per-slot int8 "
                         "caches under --backend dense)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record a lifecycle trace (repro.obs.Tracer) and "
                         "write it as a Chrome trace-event file — open in "
                         "ui.perfetto.dev (a .jsonl suffix writes "
                         "JSON-lines instead)")
    ap.add_argument("--profile", action="store_true",
                    help="install a repro.obs.DispatchProfiler on the "
                         "kernel-dispatch seam: per-phase dispatch counts, "
                         "modeled bytes and fraction-of-roofline (printed "
                         "after the run), kernel spans + streamed-bytes "
                         "counters on --trace-out, and the decode-step "
                         "dispatch audit (exits non-zero on mismatch)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write the profiler summary (phases + per-kernel "
                         "rows + audit result) as JSON; implies --profile")
    args = ap.parse_args()

    if args.tp > 1 and "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        # simulate the mesh on CPU: must land before jax is imported
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.tp}")

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import RuntimeConfig, build_model
    from repro.models import modules as M
    from repro.serve import EngineConfig, build_engine, resolve_page_size
    from repro.serve.scheduler import Request

    if args.kernel_decode and args.backend != "paged":
        raise SystemExit("--kernel-decode requires --backend paged "
                         "(the kernel reads the page pool + block table)")
    if args.chunked_prefill and args.backend != "paged":
        raise SystemExit("--chunked-prefill requires --backend paged "
                         "(slabs write through block tables)")
    if args.prefix_cache and not args.chunked_prefill:
        raise SystemExit("--prefix-cache requires --chunked-prefill (a "
                         "prefix hit resumes prefill mid-prompt)")
    if args.draft_arch is not None and not args.speculate_k:
        raise SystemExit("--draft-arch requires --speculate-k > 0 (the "
                         "draft only runs when speculation is on)")
    if args.speculate_k:
        if args.draft_arch is None:
            raise SystemExit("--speculate-k requires --draft-arch (the "
                             "draft model that proposes the lookahead)")
        if not args.chunked_prefill:
            raise SystemExit("--speculate-k requires --chunked-prefill "
                             "(the verify pass reuses the chunked slab "
                             "attention path)")
        if args.tp > 1:
            raise SystemExit("--speculate-k is single-device for now "
                             "(drop --tp)")
    kv_int8 = args.kv_cache_dtype == "int8"
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if args.speculate_k and any(m != "attn" for (m, _) in cfg.layer_kinds()):
        raise SystemExit(f"--speculate-k supports causal-attention decoder "
                         f"archs only (the verify slab goes through the "
                         f"chunked attention path); {cfg.name} mixes in "
                         f"other mixer kinds")
    if args.quantize_weights in ("mx4", "fp8") and args.tp > 1:
        from repro.quant.tensor import granule
        if args.quantize_weights == "mx4":
            raise SystemExit(
                "--quantize-weights mx4 packs fp4 row pairs that would "
                "straddle the --tp shard boundary (mirrors the int4 "
                "packed-pair rejection in tp.plan); use fp8 under TP")
        if cfg.d_model % args.tp or (cfg.d_model // args.tp) % granule():
            raise SystemExit(
                f"--quantize-weights fp8 under --tp {args.tp}: the "
                f"{granule()}-row MX scale blocks must tile each weight "
                f"shard (d_model={cfg.d_model} does not hold a whole "
                f"number of blocks per shard)")
    engine_cfg = EngineConfig(
        slots=args.slots, cache_len=args.cache_len,
        backend=args.backend, page_size=args.page_size,
        num_pages=args.num_pages,
        kv_cache_dtype="int8" if kv_int8 else "",
        chunked_prefill=args.chunked_prefill, chunk_size=args.chunk_size,
        prefix_cache=args.prefix_cache, temperature=args.temperature,
        draft_arch=args.draft_arch, speculate_k=args.speculate_k,
        tp=args.tp, tp_mode=args.tp_mode,
        async_dispatch=not args.sync_dispatch,
        kernel_decode=args.kernel_decode,
        quantize_weights=args.quantize_weights,
        quantize_group_size=args.quantize_group_size).validate()
    args.page_size = resolve_page_size(engine_cfg)
    model = build_model(cfg, RuntimeConfig(
        remat="none", paged_kernel_decode=args.kernel_decode,
        quantize_weights=args.quantize_weights,
        kv_cache_dtype="int8" if kv_int8 else ""))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    if args.quantize_weights in ("mx4", "fp8"):
        from repro.quant import quantize_params, quantized_stats
        try:
            params = quantize_params(params, fmt=args.quantize_weights,
                                     tp=args.tp)
        except AssertionError as e:
            raise SystemExit(str(e))
        qs = quantized_stats(params)
        print(f"quantized {qs['quantized_leaves']} weight leaves "
              f"({args.quantize_weights}): {qs['quantized_bytes']:,} B "
              f"(was {qs['quantized_fp32_bytes']:,} B fp32); "
              f"{qs['raw_bytes']:,} B left raw")
    elif args.quantize_weights != "none":
        from repro.quant import quantize_params, quantized_stats
        try:
            params = quantize_params(
                params, bits=8 if args.quantize_weights == "int8" else 4,
                group_size=args.quantize_group_size, tp=args.tp)
        except AssertionError as e:
            raise SystemExit(
                f"{e}\n(pass a smaller --quantize-group-size — it must "
                f"divide every projection's contraction extent"
                + (" per tp shard" if args.tp > 1 else "") + ")")
        qs = quantized_stats(params)
        print(f"quantized {qs['quantized_leaves']} weight leaves: "
              f"{qs['quantized_bytes']:,} B (was "
              f"{qs['quantized_fp32_bytes']:,} B fp32); "
              f"{qs['raw_bytes']:,} B left raw")
    draft = None
    if args.speculate_k and args.smoke:
        draft = reduced(get_config(args.draft_arch))

    extras = None
    if cfg.encoder_decoder or cfg.frontend == "vision":
        import jax.numpy as jnp
        F = cfg.cross_attention_len if cfg.encoder_decoder \
            else cfg.frontend_tokens
        extras = lambda req: {"frontend": 0.1 * jnp.ones(
            (1, F, cfg.d_model), jnp.bfloat16)}

    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    profiler = None
    if args.profile or args.profile_out:
        from repro.obs import DispatchProfiler, decode_step_account
        profiler = DispatchProfiler(tracer=tracer)
        try:
            # seed the decode phase program from the modeled account (the
            # jnp decode path never hits the registry; the dispatch audit
            # below is what licenses this substitution)
            profiler.seed_phase("decode", decode_step_account(
                cfg, slots=args.slots, cache_len=args.cache_len,
                page_size=args.page_size,
                kv_dtype="int8" if kv_int8 else "bfloat16",
                weights=args.quantize_weights
                if args.quantize_weights in ("int8", "mx4", "fp8")
                else "bfloat16",
                quant_group=args.quantize_group_size))
        except ValueError as e:
            print(f"profile: decode account unavailable ({e}); decode "
                  f"phase reports occurrences/wall only")
        profiler.install()
    engine = build_engine(model, engine_cfg, params=params, draft=draft,
                          prefill_extras=extras, tracer=tracer,
                          profiler=profiler)
    rng = np.random.default_rng(0)
    system_prompt = rng.integers(1, min(cfg.vocab_size, 1000), 24) \
        if args.prefix_cache else None
    for i in range(args.requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 1000),
                              int(rng.integers(4, 16)))
        if system_prompt is not None:       # shared header: exercise reuse
            prompt = np.concatenate([system_prompt, prompt])
        engine.submit(Request(rid=i, prompt=prompt,
                              max_new_tokens=args.max_new))
    finished = engine.run_until_drained()
    m = engine.metrics()
    print(f"served {len(finished)}/{args.requests} requests in "
          f"{engine.steps} decode steps "
          f"({m['prefill_traces']} prefill compiles, "
          f"backend={engine.backend.name})")
    print(json.dumps(m, indent=1, default=str))
    if tracer is not None:
        if args.trace_out.endswith(".jsonl"):
            tracer.to_jsonl(args.trace_out)
        else:
            tracer.to_chrome(args.trace_out)
        print(f"wrote {args.trace_out} ({len(tracer.events())} events, "
              f"{tracer.dropped} dropped)")
    if profiler is not None:
        profiler.uninstall()
        summary = profiler.summary()
        print(f"profile ({summary['spatz']}, roofline "
              f"{summary['roofline_bytes_per_s'] / 1e9:.0f} GB/s):")
        for row in summary["phases"]:
            print(f"  {row['phase']:>18s}: {row['occurrences']:5d} occ, "
                  f"{row['dispatches']:6d} dispatches, "
                  f"{row['modeled_bytes']:>14,d} B modeled, "
                  f"wall {row['wall_s'] * 1e3:8.1f} ms, "
                  f"roofline frac {row['fraction_of_roofline']:.2e}")
        audit_row = None
        if args.quantize_weights in ("none", "mx4", "fp8"):
            from repro.obs import audit_decode_step
            try:
                audit = audit_decode_step(model, cache_len=args.cache_len,
                                          page_size=args.page_size)
            except ValueError as e:
                print(f"dispatch audit skipped: {e}")
            else:
                print(audit.report())
                audit_row = {"ok": audit.ok, "arch": audit.arch,
                             "kv_dtype": audit.kv_dtype,
                             "dispatches": audit.dispatches,
                             "modeled_bytes": int(audit.measured_bytes)}
        else:
            print("dispatch audit skipped: quantized weights dequantize "
                  "in-graph (no qgemv dispatch to audit)")
        if args.profile_out:
            summary["audit"] = audit_row
            with open(args.profile_out, "w") as f:
                json.dump(summary, f, indent=1)
            print(f"wrote {args.profile_out}")
        if audit_row is not None and not audit_row["ok"]:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
