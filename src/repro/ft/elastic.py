"""Elastic scaling: rebuild the mesh after node loss and re-shard state.

With the checkpoint format (host numpy + manifest) restore-onto-any-mesh is
free; for in-memory recovery (no checkpoint round-trip) ``reshard_tree``
re-places live arrays onto the surviving mesh.  ``elastic_meshes`` yields
the shrink ladder (drop whole data rows, keeping the model axis intact —
weights never need re-partitioning, only batch re-balancing).
"""
from __future__ import annotations

from typing import List

import jax
import numpy as np
from jax.sharding import Mesh


def elastic_meshes(model_axis: int) -> List[Mesh]:
    """All meshes this host set supports, largest first (data axis ladder)."""
    n = len(jax.devices())
    out = []
    data = n // model_axis
    while data >= 1:
        devs = np.asarray(jax.devices()[:data * model_axis]).reshape(
            data, model_axis)
        out.append(Mesh(devs, ("data", "model")))
        data //= 2
    return out


def shrink_mesh(mesh: Mesh, lost_data_rows: int = 1) -> Mesh:
    """Drop ``lost_data_rows`` rows from the data axis (simulated node loss)."""
    devs = np.asarray(mesh.devices)
    assert devs.ndim == 2, "expects (data, model) mesh"
    keep = devs.shape[0] - lost_data_rows
    assert keep >= 1
    return Mesh(devs[:keep], mesh.axis_names)


def reshard_tree(tree, shardings):
    """Re-place every array onto new shardings (in-memory elastic recovery)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s), tree, shardings)
