"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Continuous-batching engine around the jitted prefill/decode steps (the
paper's decode workload): bucketed batched prefill (one compile per length
bucket), pluggable cache backend (``--backend paged`` is the default:
page-pool KV with block tables, see serve.kvcache).  ``--smoke`` uses the
reduced config on the host and prints the engine metrics.
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve.kvcache import PagedBackend
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import (make_prefill_step, make_serve_step,
                              tuned_kernel_configs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--backend", choices=("dense", "paged"), default="paged")
    ap.add_argument("--kernel-decode", action="store_true",
                    help="attend via the tuned Pallas paged kernel (no "
                         "gathered dense view; slow in CPU interpret mode)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="pool pages per layer (default: full occupancy)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    if args.kernel_decode and args.backend != "paged":
        raise SystemExit("--kernel-decode requires --backend paged "
                         "(the kernel reads the page pool + block table)")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg, RuntimeConfig(
        remat="none", paged_kernel_decode=args.kernel_decode))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))

    extras = None
    if cfg.encoder_decoder or cfg.frontend == "vision":
        import jax.numpy as jnp
        F = cfg.cross_attention_len if cfg.encoder_decoder \
            else cfg.frontend_tokens
        extras = lambda req: {"frontend": 0.1 * jnp.ones(
            (1, F, cfg.d_model), jnp.bfloat16)}

    backend = PagedBackend(page_size=args.page_size,
                           num_pages=args.num_pages) \
        if args.backend == "paged" else "dense"
    configs = tuned_kernel_configs(cfg, args.slots, args.cache_len,
                                   page_size=args.page_size,
                                   num_pages=args.num_pages)
    engine = ServingEngine(
        model, slots=args.slots, cache_len=args.cache_len,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model, temperature=args.temperature,
                                   troop_configs=configs),
        params=params, prefill_extras=extras, backend=backend)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i, prompt=rng.integers(1, min(cfg.vocab_size, 1000),
                                       int(rng.integers(4, 16))),
            max_new_tokens=args.max_new))
    finished = engine.run_until_drained()
    m = engine.metrics()
    print(f"served {len(finished)}/{args.requests} requests in "
          f"{engine.steps} decode steps "
          f"({m['prefill_traces']} prefill compiles, "
          f"backend={engine.backend.name})")
    print(json.dumps(m, indent=1, default=str))


if __name__ == "__main__":
    main()
