"""Hypothesis property tests on kernel + system invariants."""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.troop import TROOP, TroopConfig
from repro.kernels import ops as K
from repro.kernels import ref as R

SETTINGS = dict(max_examples=20, deadline=None)


def arr(key, n, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), (n,), jnp.float32,
                              lo, hi)


@settings(**SETTINGS)
@given(st.integers(0, 2**16), st.integers(0, 2**16),
       st.floats(-3, 3, allow_nan=False))
def test_axpy_linearity(k1, k2, a):
    """axpy(a,x,y) == a*x + y and is linear in x."""
    x, y = arr(k1, 1024), arr(k2, 1024)
    got = K.axpy(a, x, y, TROOP)
    np.testing.assert_allclose(got, a * x + y, rtol=1e-5, atol=1e-5)
    # linearity: axpy(a, 2x, y) - axpy(a, x, y) == a*x
    d = K.axpy(a, 2 * x, y, TROOP) - got
    np.testing.assert_allclose(d, a * x, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**16), st.integers(0, 2**16))
def test_dotp_symmetry(k1, k2):
    x, y = arr(k1, 2048), arr(k2, 2048)
    a = K.dotp(x, y, TROOP)
    b = K.dotp(y, x, TROOP)
    np.testing.assert_allclose(a, b, rtol=1e-5)
    np.testing.assert_allclose(a, R.dotp(x, y), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**16), st.floats(0.1, 10, allow_nan=False))
def test_rmsnorm_scale_invariance(k1, c):
    """rmsnorm(c*x) == rmsnorm(x) for any positive scalar c."""
    x = arr(k1, 512).reshape(4, 128) + 0.01
    s = jnp.ones((128,), jnp.float32)
    a = K.rmsnorm(x, s)
    b = K.rmsnorm(c * x, s)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**16), st.integers(2, 6))
def test_lse_combine_associativity(k1, splits):
    """Split-S decode is invariant to how the cache is partitioned."""
    B, H, KV, hd, S = 1, 4, 2, 32, 384
    ks = jax.random.split(jax.random.PRNGKey(k1), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    length = jnp.asarray([S], jnp.int32)
    want = R.decode_attention(q, k, v, length)
    # uneven split points
    cuts = np.linspace(0, S, splits + 1).astype(int)
    cuts = [c // 64 * 64 for c in cuts]          # block-aligned
    cuts = sorted(set(cuts) | {0, S})
    partials = []
    cfg = TroopConfig(streams=1, block_k=64)
    for a, b in zip(cuts[:-1], cuts[1:]):
        if a == b:
            continue
        partials.append(K.decode_attention_stats(
            q, k[:, a:b], v[:, a:b], length, cfg, s_offset=a))
    got = np.asarray(K.lse_combine(partials)).reshape(B, H, hd)
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_wkv6_chunk_invariance(k1):
    """Kernel result is independent of the chunk size (re-association)."""
    B, T, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(k1), 4)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hd))))
    u = 0.5 * jnp.ones((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    outs = []
    for bn in (64, 128, 256):    # block_n//8 -> chunk 8, 16, 32
        y, s = K.wkv6(r, k, v, w, u, s0, TroopConfig(block_n=bn))
        outs.append((np.asarray(y), np.asarray(s)))
    for y2, s2 in outs[1:]:
        np.testing.assert_allclose(outs[0][0], y2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(outs[0][1], s2, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_gemv_matches_flash_decode_degenerate(k1):
    """decode_attention with uniform probs == mean of V (consistency)."""
    B, H, KV, hd, S = 1, 2, 2, 32, 128
    kv = jax.random.split(jax.random.PRNGKey(k1), 2)
    q = jnp.zeros((B, H, hd))                   # zero q -> uniform attention
    k = jax.random.normal(kv[0], (B, S, KV, hd))
    v = jax.random.normal(kv[1], (B, S, KV, hd))
    length = jnp.asarray([S], jnp.int32)
    got = K.decode_attention(q, k, v, length, TROOP)
    want = jnp.mean(v, axis=1).reshape(B, KV, 1, hd)
    want = jnp.broadcast_to(want, (B, KV, H // KV, hd)).reshape(B, H, hd)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**16), st.integers(1, 8))
def test_data_pipeline_determinism_and_disjointness(seed, step):
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=seed)
    a = SyntheticLM(cfg, shard=0, num_shards=2)
    b = SyntheticLM(cfg, shard=0, num_shards=2)
    c = SyntheticLM(cfg, shard=1, num_shards=2)
    ba, bb, bc = a.batch_at(step), b.batch_at(step), c.batch_at(step)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])   # determinism
    assert not np.array_equal(ba["tokens"], bc["tokens"])       # disjoint


@settings(**SETTINGS)
@given(st.integers(0, 2**16))
def test_int8_compression_error_feedback_converges(seed):
    """sum of dequantized updates -> sum of true gradients (EF property)."""
    from repro.dist.compression import dequantize_int8, quantize_int8
    rng = np.random.default_rng(seed)
    g = rng.standard_normal(256).astype(np.float32)
    e = np.zeros_like(g)
    total_sent = np.zeros_like(g)
    for _ in range(50):
        q, s = quantize_int8(jnp.asarray(g + e))
        deq = np.asarray(dequantize_int8(q, s))
        e = g + e - deq
        total_sent += deq
    np.testing.assert_allclose(total_sent / 50, g, atol=2e-2)


@settings(**SETTINGS)
@given(st.integers(0, 2**16), st.floats(0.05, 8.0, allow_nan=False))
def test_mx_roundtrip_block_relative_error_bound(k1, amp):
    """MX invariants over random tensors and amplitudes: fp4/fp8
    round-trips stay within the format's relative error bound per
    32-block, E8M0 scales are exact powers of two, and the fp8 error
    never exceeds the fp4 error (format monotonicity)."""
    from repro.quant import dequantize, quantize_mx
    from repro.quant.tensor import granule

    g = granule()
    x = amp * jax.random.normal(jax.random.PRNGKey(k1), (4 * g, 16),
                                jnp.float32)
    q4 = quantize_mx(x, elem="fp4")
    q8 = quantize_mx(x, elem="fp8")
    y4 = np.asarray(dequantize(q4, jnp.float32))
    y8 = np.asarray(dequantize(q8, jnp.float32))
    xb = np.asarray(x).reshape(4, g, 16)
    amax = np.abs(xb).max(axis=1, keepdims=True)
    # shared exponent maps the block amax into [4, 8) for e2m1; the
    # coarsest code gap is 2 (4 -> 6) and the 6.0 clip loses at most
    # (8 - 6), so the worst error relative to amax approaches 1/4
    assert (np.abs(y4.reshape(4, g, 16) - xb) <= amax / 4 + 1e-6).all()
    # e4m3fn: amax scales into [256, 512), ulp there is 32 and the 448
    # clip loses at most (512 - 448) -> relative bound 1/8
    assert (np.abs(y8.reshape(4, g, 16) - xb) <= amax / 8 + 1e-6).all()
    assert np.abs(y8 - np.asarray(x)).mean() <= \
        np.abs(y4 - np.asarray(x)).mean() + 1e-7
    # E8M0: every scale decodes to an exact power of two
    from repro.quant import e8m0_decode
    s = np.asarray(e8m0_decode(q4.scales, jnp.float32))
    assert (np.log2(s) == np.round(np.log2(s))).all()
