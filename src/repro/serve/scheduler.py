"""Continuous-batching serving engine (paged KV, bucketed batched prefill).

The decode step — the paper's workload — runs every cycle over all active
slots.  Admission is *recompile-free*: queued prompts are padded to
power-of-2 length buckets and prefilled together in one fixed-size batch, so
XLA compiles at most one prefill executable per bucket, ever (the seed
engine compiled once per distinct prompt length at B=1).  Cache placement
goes through a ``CacheBackend`` (``serve.kvcache``): the paged backend
allocates block-table pages per request and frees them on finish — no
host-side ``jnp.pad`` + ``dynamic_update_slice`` splicing over the whole
tree, and no padding bytes in the decode stream.  Pure host-side control
around two jitted functions (prefill_step, serve_step), as production
engines do.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kvcache import (CacheBackend, bucket_length, make_backend,
                                 splice_row)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle metadata (filled by the engine)
    submit_step: int = -1
    admit_step: int = -1
    finish_step: int = -1

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


def splice_cache(batch_cache, one_cache, slot: int, slots: int):
    """Insert a B=1 prefill cache into slot ``slot`` of the batch cache
    (compat shim over ``kvcache.splice_row``; the engine itself splices
    through its ``CacheBackend``)."""
    return jax.tree.map(
        lambda dst, src: splice_row(dst, src, 0, slot, slots),
        batch_cache, one_cache)


class ServingEngine:
    """Slot-based continuous batching over a pluggable cache backend.

    ``backend``: 'dense' (default, the original layout), 'paged', or a
    ``CacheBackend`` instance.  ``prefill_batch`` admissions share one
    bucketed prefill call; ``min_bucket`` is the smallest prompt bucket.
    """

    def __init__(self, model, *, slots: int, cache_len: int,
                 prefill_step, serve_step, params, stop_token: int = -1,
                 prefill_extras=None, backend=None,
                 prefill_batch: Optional[int] = None, min_bucket: int = 8):
        """``prefill_extras(req) -> dict``: extra prefill batch entries
        (modality frontend stubs for enc-dec / VLM archs)."""
        self.model = model
        self.slots = slots
        self.cache_len = cache_len
        self.params = params
        self.prefill_extras = prefill_extras
        self.backend: CacheBackend = make_backend(backend)
        self.prefill_batch = prefill_batch or min(slots, 4)
        self.min_bucket = min(min_bucket, cache_len)
        # frontend tokens prepended to the decoder sequence (VLM archs)
        self._front = model.cfg.frontend_tokens \
            if getattr(model.cfg, "frontend", None) == "vision" else 0
        # right-padding a prompt is exact only for causal attention: a
        # recurrent mixer (mamba/rwkv) scans THROUGH pad tokens and hands
        # decode a polluted state — those archs prefill at exact length
        # (same-length prompts still batch; compiles are per length, as in
        # the seed engine, instead of per bucket)
        self._exact_prefill = any(
            m != "attn" for (m, f) in model.cfg.layer_kinds())

        self._prefill_traces = 0

        def counted_prefill(params, batch):
            self._prefill_traces += 1      # runs at trace time only
            return prefill_step(params, batch)

        self.prefill_step = jax.jit(counted_prefill)
        self.serve_step = jax.jit(serve_step, donate_argnums=(2,))
        self.caches = self.backend.init_caches(model, slots, cache_len)
        self.active: Dict[int, Optional[Request]] = {
            i: None for i in range(slots)}
        self.pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        # per-admission nonce: a request reusing a slot must not replay its
        # predecessor's sampling randomness at equal positions
        self._nonce = np.zeros((slots,), np.int32)
        self.queue: deque = deque()
        self.stop_token = stop_token
        self.steps = 0
        # ------------------------------------------------------- metrics
        self.tokens_generated = 0
        self.requests_admitted = 0
        self.requests_finished = 0
        self.prefill_calls = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0

    @property
    def prefill_traces(self) -> int:
        """Prefill executables compiled so far (== distinct buckets used)."""
        return self._prefill_traces

    # -------------------------------------------------------------- admit
    def submit(self, req: Request):
        # impossible requests fail HERE, loudly — once queued, a request is
        # only ever deferred (transient pool pressure), never dropped
        rows = self._front + req.prompt_len
        if rows >= self.cache_len:
            raise ValueError(
                f"prompt needs {rows} cache rows (incl. frontend) but "
                f"cache_len is {self.cache_len}")
        self.backend.check_admissible(rows + req.max_new_tokens)
        req.submit_step = self.steps
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [s for s, r in self.active.items() if r is None]

    def _admit_group(self, group, slots_for):
        """One bucketed batched prefill for ``group`` (list of Requests)."""
        if self._exact_prefill:
            bucket = group[0].prompt_len       # group is same-length
        else:
            bucket = max(bucket_length(r.prompt_len, self.min_bucket,
                                       self.cache_len) for r in group)
        Bp = self.prefill_batch
        tokens = np.zeros((Bp, bucket), np.int32)
        lengths = np.ones((Bp,), np.int32)
        for i, req in enumerate(group):
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = self._front + req.prompt_len
        batch = {"tokens": jnp.asarray(tokens),
                 "length": jnp.asarray(lengths)}
        if self.prefill_extras is not None:
            extras: Dict[str, Any] = {}
            per_req = [self.prefill_extras(r) for r in group]
            for k in per_req[0]:
                rows = [e[k] for e in per_req]
                rows += [rows[-1]] * (Bp - len(rows))   # pad batch rows
                extras[k] = jnp.concatenate(rows, axis=0)
            batch.update(extras)

        t0 = time.perf_counter()
        next_tok, prefill_caches = self.prefill_step(self.params, batch)
        next_tok = np.asarray(next_tok)
        self.prefill_calls += 1

        for i, req in enumerate(group):
            slot = slots_for[i]
            plen = self._front + req.prompt_len
            self.caches = self.backend.admit(
                self.caches, prefill_caches, row=i, slot=slot,
                prompt_len=plen)
            self.active[slot] = req
            req.admit_step = self.steps
            self.requests_admitted += 1
            self._nonce[slot] = self.requests_admitted
            self.pos[slot] = plen
            tok = int(next_tok[i])
            req.out.append(tok)
            self.tokens_generated += 1
            self.last_tok[slot] = tok
        self.prefill_s += time.perf_counter() - t0

    def _admit(self):
        """Admit as many queued requests as slots + cache capacity allow
        (possibly several bucketed prefill calls)."""
        while self.queue:
            free = self._free_slots()
            if not free:
                return
            group, slots_for = [], []
            while (self.queue and free
                   and len(group) < self.prefill_batch):
                req = self.queue[0]
                if self._exact_prefill and group \
                        and req.prompt_len != group[0].prompt_len:
                    break                      # exact-length groups only
                slot = free[0]
                need = self._front + req.prompt_len + req.max_new_tokens
                if not self.backend.reserve(slot, need):
                    break                  # pool exhausted: defer admission
                self.queue.popleft()
                free.pop(0)
                group.append(req)
                slots_for.append(slot)
            if not group:
                return
            self._admit_group(group, slots_for)

    # -------------------------------------------------------------- decode
    def step(self) -> Optional[List[Request]]:
        """One engine cycle: admit, then decode every active slot.

        Returns the requests that finished this cycle, or ``None`` when the
        engine is idle (nothing active after admission).
        """
        self._admit()
        if not any(r is not None for r in self.active.values()):
            return None
        batch = {"tokens": jnp.asarray(self.last_tok[:, None]),
                 "pos": jnp.asarray(self.pos),
                 "sample_nonce": jnp.asarray(self._nonce)}
        batch.update(self.backend.batch_extras())
        t0 = time.perf_counter()
        next_tok, self.caches = self.serve_step(
            self.params, batch, self.caches)
        toks = np.asarray(next_tok)[:, 0]
        self.decode_s += time.perf_counter() - t0
        finished: List[Request] = []
        for slot, req in self.active.items():
            if req is None:
                continue
            tok = int(toks[slot])
            req.out.append(tok)
            self.tokens_generated += 1
            self.last_tok[slot] = tok
            self.pos[slot] += 1
            if len(req.out) >= req.max_new_tokens or tok == self.stop_token \
                    or self.pos[slot] >= self.cache_len - 1:
                req.done = True
                req.finish_step = self.steps
                self.active[slot] = None
                self.backend.release(slot)
                self.requests_finished += 1
                finished.append(req)
        self.steps += 1
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Run until queue + slots are empty (or ``max_steps`` decode steps
        have run *in this call* — a long-lived engine keeps serving across
        calls); returns every request that finished during the run."""
        finished: List[Request] = []
        start = self.steps
        while (self.queue or any(r is not None
                                 for r in self.active.values())):
            if self.steps - start >= max_steps:
                break
            out = self.step()
            if out is None:
                break
            finished.extend(out)
        return finished

    # ------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, Any]:
        """Engine throughput/latency counters + backend occupancy."""
        m = {
            "decode_steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "requests_admitted": self.requests_admitted,
            "requests_finished": self.requests_finished,
            "prefill_calls": self.prefill_calls,
            "prefill_traces": self.prefill_traces,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "decode_steps_per_s": (self.steps / self.decode_s
                                   if self.decode_s else 0.0),
            "tokens_per_s": (self.tokens_generated
                             / (self.decode_s + self.prefill_s)
                             if self.decode_s + self.prefill_s else 0.0),
        }
        m.update(self.backend.stats())
        return m
