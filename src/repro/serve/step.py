"""Serving steps: prefill_step / serve_step (single-token decode).

serve_step is the paper's workload: one new token against a KV cache — every
matmul a GEMV-class memory-bound op.  Greedy sampling keeps the step a pure
function (temperature sampling derives a per-(slot, position) key so samples
are independent across the batch).

prefill_step is *bucketed*: it takes a fixed-size batch of right-padded
prompts plus their valid lengths and reads each row's next token at
``length - 1`` — so the engine compiles one prefill executable per length
bucket instead of one per distinct prompt length.

``tuned_kernel_configs`` resolves the best-known TroopConfigs for the decode
hot kernels at the serving shapes (from the persistent tune cache, heuristic
defaults when untuned) so the serving layer and kernel-backed model paths
read tuned configs from one place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tuned_kernel_configs(model_cfg, batch_size: int, max_seq: int,
                         dtype=jnp.bfloat16, page_size: int = 16,
                         num_pages=None, chunk_size: int = 32):
    """TroopConfigs for the decode-path kernels at the serving shapes.

    Pure shape-level lookup (ShapeDtypeStruct placeholders — nothing is
    allocated or traced): decode attention over the KV cache (dense and
    paged layouts) and the GEMV-class readout projection.  The paged pool
    geometry comes from ``PageSpec.for_engine`` — the same formula the
    engine allocates with — so the tuned-config key always matches the
    pool the engine will actually run (pass ``num_pages`` when
    overcommitting).
    """
    import repro.kernels  # noqa: F401  (populates the tune registry)
    from repro.quant.tensor import granule
    from repro.serve.kvcache import PageSpec
    from repro.tune import get_tuned

    sds = jax.ShapeDtypeStruct
    B, S = batch_size, max_seq
    KV, hd, H = (model_cfg.num_kv_heads, model_cfg.head_dim,
                 model_cfg.num_heads)
    d, V = model_cfg.d_model, model_cfg.vocab_size
    spec = PageSpec.for_engine(B, S, page_size, num_pages, jnp.dtype(dtype))
    P, nblk = spec.num_pages, spec.blocks_per_slot
    # int8 pages obey the coarser int8 layout granule (32 rows); the scale
    # group of the quantized readout GEMV likewise (mechanism D, DESIGN §5)
    p8 = -(-page_size // granule()) * granule()
    spec8 = PageSpec.for_engine(B, S, p8, num_pages, "int8")
    P8, nblk8 = spec8.num_pages, spec8.blocks_per_slot
    g = 128 if d % 128 == 0 else d
    return {
        "decode_attention": get_tuned(
            "decode_attention",
            sds((B, H, hd), dtype), sds((B, S, KV, hd), dtype),
            sds((B, S, KV, hd), dtype), sds((B,), jnp.int32)),
        "decode_attention_int8": get_tuned(
            "decode_attention_int8",
            sds((B, H, hd), dtype),
            sds((B, S, KV, hd), jnp.int8), sds((B, S, KV, 1), jnp.bfloat16),
            sds((B, S, KV, hd), jnp.int8), sds((B, S, KV, 1), jnp.bfloat16),
            sds((B,), jnp.int32)),
        "paged_decode_attention": get_tuned(
            "paged_decode_attention",
            sds((B, H, hd), dtype),
            sds((P, page_size, KV, hd), dtype),
            sds((P, page_size, KV, hd), dtype),
            sds((B, nblk), jnp.int32), sds((B,), jnp.int32)),
        "paged_decode_attention_int8": get_tuned(
            "paged_decode_attention_int8",
            sds((B, H, hd), dtype),
            sds((P8, p8, KV, hd), jnp.int8),
            sds((P8, p8, KV, 1), jnp.bfloat16),
            sds((P8, p8, KV, hd), jnp.int8),
            sds((P8, p8, KV, 1), jnp.bfloat16),
            sds((B, nblk8), jnp.int32), sds((B,), jnp.int32)),
        "prefill_attention_paged": get_tuned(
            "prefill_attention_paged",
            sds((1, chunk_size, H, hd), dtype),
            sds((P, page_size, KV, hd), dtype),
            sds((P, page_size, KV, hd), dtype),
            sds((1, nblk), jnp.int32), sds((1,), jnp.int32),
            sds((1,), jnp.int32)),
        "gemv": get_tuned("gemv", sds((V, d), dtype), sds((d,), dtype)),
        "qgemv": get_tuned(
            "qgemv", sds((V, d), jnp.int8), sds((V, d // g), jnp.float32),
            sds((d,), dtype)),
        "rmsnorm": get_tuned("rmsnorm", sds((B, d), dtype),
                             sds((d,), jnp.float32)),
    }


def make_chunk_step(model):
    """Chunked prefill: one fixed-size token slab against the shared paged
    caches.  batch = {tokens (1, C) right-padded, offset (1,), valid (1,),
    stage_base (1,), block_tables (1, nblk)} -> (next_tok (1,), caches).
    The returned token is the greedy argmax of the last valid row's logits
    — only meaningful on a prompt's final slab (identical readout to the
    bucketed ``make_prefill_step``, so the two engines emit the same first
    token)."""
    def chunk_step(params, batch, caches):
        logits, caches = model.chunk_step(params, batch, caches)  # (B, V)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
    return chunk_step


def make_draft_step(model):
    """Speculative draft forward: a chunked slab against the draft model's
    own paged caches, returning the full fp32 logits row of each slot's
    last valid position (the scheduler samples/argmaxes on the host so one
    compiled function serves greedy and temperature drafting).  batch =
    {tokens (B, W) right-padded, offset (B,), valid (B,), stage_base (B,),
    block_tables (B, nblk)} -> (logits (B, V), caches)."""
    def draft_step(params, batch, caches):
        logits, caches = model.chunk_step(params, batch, caches)  # (B, V)
        return logits.astype(jnp.float32), caches
    return draft_step


def make_verify_step(model):
    """Speculative verify forward: score all W rows of the slab in one
    target weight pass (the TROOP lever — (k+1)x tokens per byte of
    weights/KV streamed).  batch = {tokens (B, W), offset (B,),
    valid (B,), block_tables (B, nblk)} -> (logits (B, W, V) fp32,
    caches); row i scores position offset + i + 1."""
    def verify_step(params, batch, caches):
        logits, caches = model.verify_step(params, batch, caches)
        return logits.astype(jnp.float32), caches
    return verify_step


def make_prefill_step(model):
    """Bucketed batched prefill: batch = {tokens (Bp, L) right-padded,
    length (Bp,) valid rows incl. any frontend prefix} -> (next_tok (Bp,),
    caches).  Without ``length`` the last position is read (B=1 compat)."""
    def prefill_step(params, batch):
        length = batch.get("length")
        feed = {k: v for k, v in batch.items() if k != "length"}
        logits, caches = model.prefill(params, feed)
        if length is None:
            next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            return next_tok.astype(jnp.int32), caches
        # gather each row's last valid position first: O(Bp*V) argmax
        # instead of O(Bp*L*V) over positions that are then discarded
        last = jnp.take_along_axis(
            logits, (length - 1)[:, None, None], axis=1)[:, 0]   # (Bp, V)
        next_tok = jnp.argmax(last, axis=-1)
        return next_tok.astype(jnp.int32), caches
    return prefill_step


def sample_keys(pos, batch_size: int, seed: int = 0, nonce=None):
    """Per-(request, slot, position) sampling keys: fold the slot index,
    the row's position, and a per-admission ``nonce`` into one base key, so
    no two slots, no two steps of one slot, and no two requests reusing a
    slot ever share a key (the seed engine folded only ``pos[0]``, giving
    every slot the same key each step: correlated samples — and without
    the nonce, a request re-admitted to the same slot would replay its
    predecessor's randomness position for position)."""
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        base, jnp.arange(batch_size))
    keys = jax.vmap(jax.random.fold_in)(keys, pos)
    if nonce is not None:
        keys = jax.vmap(jax.random.fold_in)(keys, nonce)
    return keys


def make_serve_step(model, *, temperature: float = 0.0, seed: int = 0,
                    troop_configs=None):
    """``troop_configs`` (from ``tuned_kernel_configs``) is attached to the
    returned step for kernel-backed decode paths and introspection."""
    def serve_step(params, batch, caches):
        logits, caches = model.decode_step(params, batch, caches)
        if temperature > 0:
            keys = sample_keys(batch["pos"], batch["pos"].shape[0], seed,
                               nonce=batch.get("sample_nonce"))
            next_tok = jax.vmap(
                lambda k, row: jax.random.categorical(k, row / temperature)
            )(keys, logits[:, -1, :])[:, None].astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, caches
    serve_step.troop_configs = troop_configs
    return serve_step
