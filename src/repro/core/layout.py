"""Weight/cache layouts — the software analogue of TCDM address scrambling.

Paper mechanism (E): offset L1 rows so two decoupled interfaces never target
the same bank.  HBM has no programmer-visible banks, but the same failure
mode exists: two DMA streams walking *strided* or *overlapping* address
ranges serialize on the memory controller.  The cure is layout: store the
operand pre-tiled so each (stream, grid-step) fetch is one dense contiguous
region, disjoint from the other stream's.

``tile_weight``/``untile_weight`` convert (N, K) row-major weights to
(N/bn, K/bk, bn, bk) tile-major; ``verify_alignment`` enforces the
hardware granule (D): tiles must be multiples of (sublane, 128).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.troop import sublane


def tile_weight(w, bn: int, bk: int):
    """(N, K) -> (N/bn, K/bk, bn, bk) tile-major (each tile contiguous)."""
    N, K = w.shape
    assert N % bn == 0 and K % bk == 0, (N, K, bn, bk)
    return (w.reshape(N // bn, bn, K // bk, bk)
             .transpose(0, 2, 1, 3)
             .copy() if hasattr(w, "copy") else
            w.reshape(N // bn, bn, K // bk, bk).transpose(0, 2, 1, 3))


def untile_weight(wt):
    """(Nb, Kb, bn, bk) -> (N, K)."""
    Nb, Kb, bn, bk = wt.shape
    return wt.transpose(0, 2, 1, 3).reshape(Nb * bn, Kb * bk)


def verify_alignment(shape, dtype, lane_dim: int = -1):
    """True iff the minor dim is lane-aligned (128) and the second-minor is
    sublane-aligned for the dtype — mechanism (D)."""
    if len(shape) < 2:
        return shape[-1] % 128 == 0
    return shape[lane_dim] % 128 == 0 and \
        shape[lane_dim - 1] % sublane(dtype) == 0


def stream_regions(total: int, streams: int):
    """Contiguous half-split (the paper's coarse-grained VLSU decoupling):
    stream i owns [i*total/streams, (i+1)*total/streams)."""
    step = total // streams
    return [(i * step, (i + 1) * step) for i in range(streams)]
