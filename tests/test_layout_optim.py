"""core.layout + optimizer equivalence tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layout as L
from repro.core.troop import BASELINE, TROOP
from repro.optim import OptConfig, make_optimizer


def test_tile_untile_roundtrip():
    w = jnp.arange(64 * 32.0).reshape(64, 32)
    t = L.tile_weight(w, 16, 8)
    assert t.shape == (4, 4, 16, 8)
    np.testing.assert_array_equal(L.untile_weight(t), w)


def test_alignment_checks():
    assert L.verify_alignment((256, 128), jnp.float32)
    assert not L.verify_alignment((256, 100), jnp.float32)
    assert L.verify_alignment((16, 128), jnp.bfloat16)
    assert not L.verify_alignment((8, 128), jnp.bfloat16)  # bf16 sublane 16


def test_stream_regions_disjoint_contiguous():
    regs = L.stream_regions(1024, 2)
    assert regs == [(0, 512), (512, 1024)]


def _params():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    return {"w": jax.random.normal(ks[0], (64, 32)),
            "b": jax.random.normal(ks[1], (32,))}


def test_fused_optimizer_equals_reference_over_steps():
    cfg_ref = OptConfig(lr=1e-2, warmup_steps=1, fused=False)
    cfg_fused = OptConfig(lr=1e-2, warmup_steps=1, fused=True)
    p1, p2 = _params(), _params()
    o1, o2 = make_optimizer(cfg_ref), make_optimizer(cfg_fused)
    s1, s2 = o1.init(p1), o2.init(p2)
    for i in range(4):
        g = jax.tree.map(
            lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(i), p.shape),
            p1)
        p1, s1, _ = o1.update(g, s1, p1)
        p2, s2, _ = o2.update(g, s2, p2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=1e-5, atol=1e-6), p1, p2)


def test_lion_and_sgdm_run():
    for name in ("lion", "sgdm"):
        opt = make_optimizer(OptConfig(name=name, lr=1e-2))
        p = _params()
        s = opt.init(p)
        g = jax.tree.map(jnp.ones_like, p)
        p2, s, lr = opt.update(g, s, p)
        assert jnp.isfinite(lr)
        assert not jax.tree.all(jax.tree.map(
            lambda a, b: jnp.array_equal(a, b), p, p2))
