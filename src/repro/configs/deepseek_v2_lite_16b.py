"""deepseek-v2-lite-16b — MLA (kv_lora=512) + fine-grained MoE.

[arXiv:2405.04434; hf]  27L d_model=2048 16H d_ff(moe)=1408 vocab=102400,
MoE 64 routed top-6 + 2 shared, first layer dense (d_ff=10944).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                # dense ffn used by the first layer
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, num_experts_per_tok=6, d_ff=1408,
                  num_shared_experts=2, shared_d_ff=2816,
                  norm_topk_prob=False, routed_scaling_factor=1.0),
    first_dense_layers=1,
    rope_theta=10_000.0,
)
