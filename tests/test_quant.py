"""repro.quant: QuantizedTensor roundtrips, int4 packing, the policy pass
over model params, fused-dequant kernels vs their oracles, int8 page pools
(paged vs dense vs bf16 engine parity + allocator accounting), and the
dedup of the historical int8 helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K
from repro.configs import get_config, reduced
from repro.core.troop import BASELINE, TROOP
from repro.kernels import ref as R
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.quant import (QuantizedTensor, dequantize, pack_int4,
                         quantize, quantize_params, quantized_stats,
                         unpack_int4)
from repro.serve import EngineConfig
from repro.serve.kvcache import PagedBackend
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step


# --------------------------------------------------------------------------
# tensor layer
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bits,rtol", [(8, 1e-2), (4, 2e-1)])
@pytest.mark.parametrize("shape,axis", [((64, 256), -1), ((256, 64), -2),
                                        ((3, 64, 256), -1)])
def test_quantize_dequantize_roundtrip(bits, rtol, shape, axis):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    qt = quantize(x, bits=bits, group_size=128, axis=axis)
    assert qt.values.dtype == jnp.int8
    assert qt.shape == shape
    y = dequantize(qt, jnp.float32)
    assert float(jnp.max(jnp.abs(y - x))) <= rtol * float(jnp.max(jnp.abs(x)))


def test_quantize_per_tensor_scalar_scale():
    x = jax.random.normal(jax.random.PRNGKey(1), (333,), jnp.float32)
    qt = quantize(x, bits=8, axis=None)
    assert qt.scales.shape == ()
    y = dequantize(qt)
    assert float(jnp.max(jnp.abs(y - x))) <= 1.5e-2 * float(jnp.max(jnp.abs(x)))


def test_int4_pack_unpack_exact():
    q = jnp.asarray(np.random.default_rng(0).integers(-7, 8, (16, 64)),
                    jnp.int8)
    for axis in (-1, 0):
        assert np.array_equal(np.asarray(unpack_int4(pack_int4(q, axis),
                                                     axis)), np.asarray(q))


def test_quantized_tensor_is_a_pytree_and_scan_slices():
    """Stacked (L, in, out) weights slice through tree ops exactly like a
    scanned layer group: the negative grouped axis survives."""
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 128, 64), jnp.float32)
    qt = quantize(w, bits=8, group_size=128, axis=-2)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2
    assert jax.tree_util.tree_unflatten(treedef, leaves) == qt
    sliced = jax.tree.map(lambda v: v[1], qt)
    np.testing.assert_allclose(np.asarray(dequantize(sliced)),
                               np.asarray(dequantize(qt))[1], rtol=1e-6)


def test_group_size_must_align_with_granule():
    params = {"wq": {"w": jnp.ones((64, 64), jnp.float32)}}
    with pytest.raises(AssertionError, match="granule"):
        quantize_params(params, group_size=48)


def test_scale_blocks_align_with_kernel_tiles():
    """Mechanism-D alignment: the scale group divides every block_k the
    qgemv space can pick, so no scale block straddles a tile edge."""
    from repro.tune import REGISTRY
    for bk in REGISTRY["qgemv"].space["block_k"]:
        assert bk % 128 == 0


# --------------------------------------------------------------------------
# quantize_params policy
# --------------------------------------------------------------------------
def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        keys = tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        out[keys] = leaf
    return out


def test_quantize_params_policy_moe_arch():
    """MLP/attention projections quantize; embeddings, norms, router and
    the raw-einsum MoE expert stacks stay untouched."""
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg, RuntimeConfig(remat="none", moe_groups=1))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    qp = quantize_params(params, bits=8)
    for keys, leaf in _leaf_paths(qp).items():
        q = isinstance(leaf, QuantizedTensor) or (
            hasattr(leaf, "dtype") and leaf.dtype == jnp.int8)
        if "embed" in keys or "router" in keys or "norm1" in keys \
                or "final_norm" in keys:
            assert not q, keys
        if keys[-2:] == ("wq", "w"):
            assert q, keys
    stats = quantized_stats(qp)
    assert stats["quantized_leaves"] > 0
    # MoE expert stacks (sibling of the router) stay raw
    raw = _leaf_paths(params)
    for keys, leaf in raw.items():
        if "router" in keys:
            prefix = keys[:keys.index("router")]
            for k2, l2 in _leaf_paths(qp).items():
                if k2[:len(prefix)] == prefix and "wi_up" in k2 \
                        and "shared" not in k2:
                    assert not isinstance(l2, QuantizedTensor), k2


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("bits,rtol", [(8, 0.05)])
def test_quantized_forward_tracks_fp(arch, bits, rtol):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, RuntimeConfig(remat="none", moe_groups=1))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    qp = quantize_params(params, bits=bits)
    toks = jnp.arange(2 * 8).reshape(2, 8) % 7 + 1
    lf, _ = model.train_logits(params, {"tokens": toks})
    lq, _ = model.train_logits(qp, {"tokens": toks})
    rel = float(jnp.max(jnp.abs(lq - lf)) / jnp.max(jnp.abs(lf)))
    assert rel < rtol, rel


# --------------------------------------------------------------------------
# fused-dequant kernels
# --------------------------------------------------------------------------
@pytest.mark.parametrize("N,K_", [(256, 1024), (128, 512)])
@pytest.mark.parametrize("bits", [8, 4])
def test_qgemv(N, K_, bits):
    w = jax.random.normal(jax.random.PRNGKey(0), (N, K_), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (K_,), jnp.bfloat16)
    qt = quantize(w, bits=bits, group_size=128, axis=-1)
    # bits is carried explicitly (no shape heuristic): a (N, K/2) int8
    # buffer could equally be a narrow 8-bit weight
    want = np.asarray(R.qgemv(qt.values, qt.scales, x, bits=bits))
    for cfg in (BASELINE, TROOP):
        got = np.asarray(K.qgemv(qt.values, qt.scales, x, cfg, bits=bits))
        # exact vs the dequantized oracle (isolates kernel error)
        np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)
    # within quantization noise of the fp32 oracle
    full = np.asarray(R.gemv(w, x.astype(jnp.float32)))
    tol = 2e-2 if bits == 8 else 2e-1
    assert np.max(np.abs(want - full)) <= tol * np.max(np.abs(full))


@pytest.mark.parametrize("B", [1, 4])
def test_batched_qgemv(B):
    N, K_ = 128, 512
    w = jax.random.normal(jax.random.PRNGKey(0), (N, K_), jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, K_), jnp.bfloat16)
    qt = quantize(w, bits=8, group_size=128, axis=-1)
    want = np.asarray(R.batched_qgemv(qt.values, qt.scales, xs))
    for cfg in (BASELINE, TROOP):
        got = np.asarray(K.batched_qgemv(qt.values, qt.scales, xs, cfg))
        np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_qgemv_bytes_under_point6_of_bf16():
    """The acceptance bound: modeled qgemv bytes <= 0.6x bf16 gemv bytes
    at the same logical shape (int8 + scale traffic vs bf16)."""
    from repro.tune import REGISTRY
    sds = jax.ShapeDtypeStruct
    N, K_ = 2048, 4096
    bf = REGISTRY["gemv"].bytes(sds((N, K_), jnp.bfloat16),
                                sds((K_,), jnp.bfloat16))
    q8 = REGISTRY["qgemv"].bytes(sds((N, K_), jnp.int8),
                                 sds((N, K_ // 128), jnp.float32),
                                 sds((K_,), jnp.bfloat16))
    q4 = REGISTRY["qgemv"].bytes(sds((N, K_ // 2), jnp.int8),
                                 sds((N, K_ // 128), jnp.float32),
                                 sds((K_,), jnp.bfloat16))
    assert q8 <= 0.6 * bf
    assert q4 <= 0.35 * bf


@pytest.mark.parametrize("B,H,KV,hd,page,nblk", [
    (2, 8, 8, 64, 32, 8), (2, 8, 2, 64, 32, 3), (1, 16, 4, 128, 32, 4),
])
def test_paged_decode_attention_int8(B, H, KV, hd, page, nblk):
    """int8 pools + scale pages through the block-table gather == the
    dequantized oracle (incl. odd-nblk one-stream fallback)."""
    from repro.quant import quantize_kv
    P = 1 + B * nblk
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), jnp.float32)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), jnp.float32)
    k8, ksc = quantize_kv(k_pool)
    v8, vsc = quantize_kv(v_pool)
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)
    S = nblk * page
    length = jnp.asarray([(S // 2 + 17 * b) % S + 1 for b in range(B)],
                         jnp.int32)
    want = np.asarray(
        R.paged_decode_attention_int8(q, k8, ksc, v8, vsc, bt, length),
        np.float32)
    for cfg in (BASELINE, TROOP):
        got = np.asarray(
            K.paged_decode_attention_int8(q, k8, ksc, v8, vsc, bt, length,
                                          cfg), np.float32)
        np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


# --------------------------------------------------------------------------
# int8 paged engine: parity + allocator accounting (two archs)
# --------------------------------------------------------------------------
def _engine(model, params, backend, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("cache_len", 64)
    kw.setdefault("min_bucket", 4)
    name = backend if isinstance(backend, str) else backend.name
    return ServingEngine(
        model, prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params, backend=backend,
        config=EngineConfig(backend=name, **kw))


def _serve(model, params, backend):
    eng = _engine(model, params, backend)
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + 2 * i) % 63 + 1,
                    max_new_tokens=6) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == len(reqs)
    return {r.rid: r.out for r in reqs}, eng


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "glm4-9b"])
def test_paged_int8_matches_dense_int8_and_tracks_bf16(arch):
    """Token-identical greedy outputs: paged-int8 == dense-int8 (same
    quantization, different layout); and the int8 decode logits stay
    within quantization tolerance of the bf16 engine's."""
    cfg = reduced(get_config(arch),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    rt8 = RuntimeConfig(remat="none", kv_cache_dtype="int8")
    model8 = build_model(cfg, rt8)
    model_bf = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model_bf.init(jax.random.PRNGKey(0)))

    out_d8, _ = _serve(model8, params, "dense")
    out_p8, eng8 = _serve(model8, params,
                          PagedBackend(page_size=32, kv_dtype="int8"))
    assert out_p8 == out_d8

    # int8 vs bf16: compare one decode step's logits (greedy tokens can
    # legitimately flip near ties under quantization noise)
    eng_bf = _engine(model_bf, params, "paged")
    eng8b = _engine(model8, params, PagedBackend(page_size=32,
                                                 kv_dtype="int8"))
    prompt = np.asarray([3, 14, 15, 9], np.int32)
    for eng, model in ((eng_bf, model_bf), (eng8b, model8)):
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
        eng.step()
    batch = {"tokens": jnp.asarray(eng_bf.last_tok[:, None]),
             "pos": jnp.asarray(eng_bf.pos)}
    l_bf, _ = model_bf.decode_step(
        params, dict(batch, **eng_bf.backend.batch_extras()), eng_bf.caches)
    l_q8, _ = model8.decode_step(
        params, dict(batch, **eng8b.backend.batch_extras()), eng8b.caches)
    np.testing.assert_allclose(
        np.asarray(l_q8[0], np.float32), np.asarray(l_bf[0], np.float32),
        rtol=0.15, atol=0.15)


def test_paged_int8_allocator_accounting_no_leaked_pages():
    """Scale pages ride the value pages' table entries: the allocator is
    unchanged, and a drained engine returns every page."""
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none",
                                           kv_cache_dtype="int8"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    backend = PagedBackend(page_size=32, kv_dtype="int8")
    _, eng = _serve(model, params, backend)
    assert backend.allocator.num_free == backend.spec.num_pages - 1
    assert backend.spec.kv_dtype == "int8"
    leaf = eng.caches[0][0]["mixer"]
    assert leaf.quantized and leaf.k_pool.dtype == jnp.int8
    assert leaf.k_scale_pool.shape == leaf.k_pool.shape[:-1] + (1,)
    # int8 pages obey the coarser 32-row granule (mechanism D)
    with pytest.raises(AssertionError, match="granule"):
        PagedBackend(page_size=16, kv_dtype="int8").init_caches(
            model, 2, 64)


def test_paged_int8_kernel_decode_matches_jnp_path():
    """paged_kernel_decode=True routes a quantized paged cache through the
    fused-dequant Pallas kernel; logits match the jnp gather path."""
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    rt = RuntimeConfig(remat="none", kv_cache_dtype="int8")
    model = build_model(cfg, rt)
    kmodel = build_model(cfg, RuntimeConfig(
        remat="none", kv_cache_dtype="int8", paged_kernel_decode=True))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    eng = _engine(model, params, PagedBackend(page_size=32, kv_dtype="int8"),
                  slots=2)
    eng.submit(Request(rid=0, prompt=np.asarray([3, 14, 15, 9], np.int32),
                       max_new_tokens=2))
    eng.step()
    batch = {"tokens": jnp.asarray(eng.last_tok[:, None]),
             "pos": jnp.asarray(eng.pos)}
    batch.update(eng.backend.batch_extras())
    l_jnp, _ = model.decode_step(params, batch, eng.caches)
    l_ker, _ = kmodel.decode_step(params, batch, eng.caches)
    np.testing.assert_allclose(
        np.asarray(l_ker[0], np.float32), np.asarray(l_jnp[0], np.float32),
        rtol=3e-2, atol=3e-2)


def test_quantized_weights_serve_end_to_end():
    """--quantize-weights in engine form: quantized params decode greedily
    and the byte accounting shows the shrink."""
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none",
                                           quantize_weights="int8"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    qp = quantize_params(params, bits=8)
    stats = quantized_stats(qp)
    assert stats["quantized_leaves"] >= 8
    out, _ = _serve(model, qp, "paged")
    assert all(len(v) == 6 for v in out.values())


# --------------------------------------------------------------------------
# dedup of the historical helpers
# --------------------------------------------------------------------------
def test_attention_quantize_kv_matches_historical_formula():
    from repro.models.attention import dequantize_kv, quantize_kv
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 64), jnp.float32)
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.bfloat16
    assert s.shape == (2, 16, 4, 1)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    want_s = jnp.maximum(amax / 127.0, 1e-8).astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(s, np.float32),
                                  np.asarray(want_s, np.float32))
    y = dequantize_kv(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(y - x))) < 2e-2 * float(jnp.max(jnp.abs(x)))


def test_dist_compression_wrappers_roundtrip():
    from repro.dist.compression import dequantize_int8, quantize_int8
    g = jax.random.normal(jax.random.PRNGKey(0), (257,), jnp.float32)
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8 and s.shape == ()
    deq = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) * 0.5 + 1e-6


# --------------------------------------------------------------------------
# MX microscaling (mx4 / fp8, DESIGN.md §11)
# --------------------------------------------------------------------------
from repro.quant import (e8m0_decode, fp4_decode, fp4_encode,  # noqa: E402
                         pack_fp4, quantize_mx, unpack_fp4)
from repro.quant.tensor import FP8_DTYPE, granule  # noqa: E402


def test_fp4_code_roundtrip_exact():
    """Every representable e2m1 value encodes to itself."""
    vals = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    xs = jnp.asarray([v * s for v in vals for s in (1.0, -1.0)], jnp.float32)
    codes = fp4_encode(xs)
    np.testing.assert_array_equal(np.asarray(fp4_decode(codes), np.float32),
                                  np.asarray(xs))
    # and the nibble pack/unpack is lossless along the leading axis
    c2 = codes.reshape(4, 4)
    np.testing.assert_array_equal(np.asarray(unpack_fp4(pack_fp4(c2))),
                                  np.asarray(c2))


def test_fp4_encode_rounds_to_nearest():
    # midpoints resolve to a neighbouring representable magnitude
    xs = jnp.asarray([0.2, 0.8, 1.2, 2.4, 5.5, -3.4], jnp.float32)
    got = np.asarray(fp4_decode(fp4_encode(xs)), np.float32)
    grid = np.asarray([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
    for x, g in zip(np.asarray(xs), got):
        best = grid[np.argmin(np.abs(grid - abs(x)))] * np.sign(x)
        assert g == best, (x, g, best)


@pytest.mark.parametrize("elem,max_rel", [("fp4", 0.30), ("fp8", 0.10)])
def test_quantize_mx_roundtrip(elem, max_rel):
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 64), jnp.float32)
    qt = quantize_mx(x, elem=elem)
    assert qt.fmt == "mx" and qt.axis == -2
    assert qt.group_size == granule()
    assert qt.scales.dtype == jnp.uint8          # E8M0 shared exponents
    assert qt.shape == x.shape
    if elem == "fp4":
        assert qt.values.dtype == jnp.uint8 and qt.bits == 4
        assert qt.values.shape == (128, 64)      # two codes per byte
    else:
        assert qt.values.dtype == FP8_DTYPE and qt.bits == 8
    y = np.asarray(dequantize(qt, jnp.float32))
    err = np.max(np.abs(y - np.asarray(x)))
    # block-relative: each 32-block scales to its own amax
    assert err <= max_rel * float(jnp.max(jnp.abs(x)))


def test_mx_error_monotone_fp8_beats_fp4():
    x = jax.random.normal(jax.random.PRNGKey(1), (512, 32), jnp.float32)
    e4 = float(jnp.mean(jnp.abs(
        dequantize(quantize_mx(x, elem="fp4"), jnp.float32) - x)))
    e8 = float(jnp.mean(jnp.abs(
        dequantize(quantize_mx(x, elem="fp8"), jnp.float32) - x)))
    assert e8 <= e4


def test_mx_bytes_ratios():
    """The headline roofline move: mx4 <= 0.28x and fp8 <= 0.55x of the
    bf16 weight bytes at a serving shape (values + E8M0 traffic)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 2048), jnp.float32)
    bf16 = x.size * 2
    assert quantize_mx(x, elem="fp4").nbytes <= 0.28 * bf16
    assert quantize_mx(x, elem="fp8").nbytes <= 0.55 * bf16


def test_quantize_mx_odd_k_falls_back_to_fp8():
    x = jax.random.normal(jax.random.PRNGKey(2), (33, 8), jnp.float32)
    qt = quantize_mx(x, elem="fp4")
    assert qt.values.dtype == FP8_DTYPE and qt.bits == 8
    y = np.asarray(dequantize(qt, jnp.float32))
    assert np.max(np.abs(y - np.asarray(x))) <= 0.1 * float(
        jnp.max(jnp.abs(x)))


def test_quantize_params_mx_policy_flips_expert_stacks():
    """Under MX the MoE expert stacks DO quantize (grouped_expert_qgemv
    consumes them); router/norms/embeds stay raw, exactly as under int8."""
    def qt_paths(tree):
        flat, _ = jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        return {tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path): leaf for path, leaf in flat}

    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg, RuntimeConfig(remat="none", moe_groups=1))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    qp = quantize_params(params, fmt="mx4")
    paths = qt_paths(qp)
    expert_q = [k for k, v in paths.items()
                if isinstance(v, QuantizedTensor) and v.fmt == "mx"
                and "wi_up" in k and "shared" not in k]
    assert expert_q, "MX must quantize the routed expert stacks"
    for keys, leaf in paths.items():
        if "embed" in keys or "router" in keys or "norm1" in keys \
                or "final_norm" in keys:
            assert not isinstance(leaf, QuantizedTensor), keys
    # and fp8 follows the same policy with 8-bit elements
    qp8 = qt_paths(quantize_params(params, fmt="fp8"))
    for k in expert_q:
        assert qp8[k].bits == 8 and qp8[k].fmt == "mx", k


def test_quantize_params_mx4_rejects_tp():
    params = {"wq": {"w": jnp.ones((64, 64), jnp.float32)}}
    with pytest.raises(AssertionError):
        quantize_params(params, fmt="mx4", tp=2)


@pytest.mark.parametrize("elem", ["fp4", "fp8"])
def test_mx_qgemv_matches_oracle(elem):
    N, K_ = 128, 512
    w = jax.random.normal(jax.random.PRNGKey(0), (K_, N), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (K_,), jnp.float32)
    qt = quantize_mx(w, elem=elem)
    want = np.asarray(R.mx_qgemv(qt.values, qt.scales, x))
    for cfg in (BASELINE, TROOP):
        got = np.asarray(K.mx_qgemv(qt.values, qt.scales, x, cfg))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    # within quantization noise of the fp32 oracle
    full = np.asarray(R.gemv(w.T, x))
    tol = 0.35 if elem == "fp4" else 0.1
    assert np.max(np.abs(want - full)) <= tol * np.max(np.abs(full))


@pytest.mark.parametrize("B", [1, 4])
def test_batched_mx_qgemv_matches_oracle(B):
    N, K_ = 128, 256
    w = jax.random.normal(jax.random.PRNGKey(0), (K_, N), jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, K_), jnp.float32)
    qt = quantize_mx(w, elem="fp4")
    want = np.asarray(R.batched_mx_qgemv(qt.values, qt.scales, xs))
    for cfg in (BASELINE, TROOP):
        got = np.asarray(K.batched_mx_qgemv(qt.values, qt.scales, xs, cfg))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("elem", ["fp4", "fp8"])
def test_mx_qgemv_swiglu_matches_oracle(elem):
    d, f = 256, 128
    kg, ku, kx = jax.random.split(jax.random.PRNGKey(0), 3)
    qg = quantize_mx(jax.random.normal(kg, (d, f), jnp.float32), elem=elem)
    qu = quantize_mx(jax.random.normal(ku, (d, f), jnp.float32), elem=elem)
    x = jax.random.normal(kx, (d,), jnp.float32)
    want = np.asarray(R.mx_qgemv_swiglu(qg.values, qg.scales,
                                        qu.values, qu.scales, x))
    got = np.asarray(K.mx_qgemv_swiglu(qg.values, qg.scales,
                                       qu.values, qu.scales, x))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("elem", ["fp4", "fp8"])
def test_grouped_expert_qgemv_token_identical_to_gather(elem):
    """The routed expert dispatch == dequantize-then-einsum over the
    gathered stacks, for every expert-id pattern."""
    E, K_, N, topk = 4, 128, 64, 2
    w = jax.random.normal(jax.random.PRNGKey(0), (E, K_, N), jnp.float32)
    qt = quantize_mx(w, elem=elem)
    xs = jax.random.normal(jax.random.PRNGKey(1), (topk, K_), jnp.float32)
    for ids in ([0, 0], [1, 3], [3, 2]):
        ids_a = jnp.asarray(ids, jnp.int32)
        want = np.asarray(R.grouped_expert_qgemv(qt.values, qt.scales,
                                                 xs, ids_a))
        got = np.asarray(K.grouped_expert_qgemv(qt.values, qt.scales,
                                                xs, ids_a))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_mx_engine_end_to_end_within_int4_tolerance():
    """mx4-quantized MoE engine: decodes greedily end-to-end, and its
    prefill logits stay within the int4 error envelope of the fp oracle."""
    from repro.models.transformer import prefill
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jnp.arange(1, 5)[None, :],
             "positions": jnp.arange(4)[None, :]}
    ref, _ = prefill(params, cfg, model.rt, batch)
    ref = np.asarray(ref, np.float32)
    scale = np.max(np.abs(ref)) + 1e-9

    def err(qp):
        lg, _ = prefill(qp, cfg, model.rt, batch)
        return np.max(np.abs(np.asarray(lg, np.float32) - ref)) / scale

    e_mx4 = err(quantize_params(params, fmt="mx4"))
    e_int4 = err(quantize_params(params, bits=4))
    e_fp8 = err(quantize_params(params, fmt="fp8"))
    assert e_mx4 <= max(e_int4, 0.30) * 1.25, (e_mx4, e_int4)
    assert e_fp8 <= e_mx4

    # and the engine drains under mx4 (the --quantize-weights mx4 path)
    qp = quantize_params(params, fmt="mx4")
    out, _ = _serve(model, qp, "paged")
    assert all(len(v) == 6 for v in out.values())


def test_mx_routed_decode_matches_gather_path():
    """kernel_routing ON routes mx_qgemv / mx_qgemv_swiglu /
    grouped_expert_qgemv; the step output tracks the in-graph dequant
    path to accumulation precision."""
    from repro.models.transformer import decode_step, init_caches
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    qp = quantize_params(params, fmt="mx4")
    db = {"tokens": jnp.array([[7]]), "pos": jnp.array([0])}
    caches = init_caches(cfg, model.rt, 1, 64, jnp.float32)
    a, _ = decode_step(qp, cfg, model.rt, db, caches)
    caches = init_caches(cfg, model.rt, 1, 64, jnp.float32)
    with M.kernel_routing():
        b, _ = decode_step(qp, cfg, model.rt, db, caches)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("fmt", ["mx4", "fp8"])
def test_audit_decode_step_mx_exact(fmt):
    """The acceptance bar: a quantized-MoE decode step audits byte-exact
    (kernel multiset AND modeled bytes) against decode_step_account."""
    from repro import obs
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    model = build_model(cfg, RuntimeConfig(remat="none",
                                           quantize_weights=fmt))
    a = obs.audit_decode_step(model, cache_len=64, page_size=16)
    assert a.ok, a.report()
    assert a.dispatches == sum(a.expected.values())
    assert a.measured_bytes == a.expected_bytes > 0


def test_engine_config_mx_validation():
    assert EngineConfig(quantize_weights="mx4").validate()
    assert EngineConfig(quantize_weights="fp8", tp=1).validate()
    with pytest.raises(ValueError, match="mx4"):
        EngineConfig(quantize_weights="mx4", tp=2).validate()
    with pytest.raises(ValueError):
        EngineConfig(quantize_weights="mx5").validate()
