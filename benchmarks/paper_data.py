"""Published numbers from the TROOP paper (targets for validation)."""

# Fig. 5 FPU utilizations (fractions). None = not quoted numerically in text;
# GEMV/GEMM baseline read off the figure approximately.
FIG5 = {
    "dotp": {"Spatz_BASELINE": 0.33, "Spatz_2xBW": 0.59,
             "Spatz_2xBW_TROOP": 0.76},
    "axpy": {"Spatz_BASELINE": 0.21, "Spatz_2xBW": 0.44,
             "Spatz_2xBW_TROOP": 0.55},
    "gemv": {"Spatz_BASELINE": None, "Spatz_2xBW": 0.92,
             "Spatz_2xBW_TROOP": 0.98},
    "gemm": {"Spatz_BASELINE": 1.00, "Spatz_2xBW": 1.00,
             "Spatz_2xBW_TROOP": 1.00},
}
DOTP_LONG = {"Spatz_2xBW": 0.70, "Spatz_2xBW_TROOP": 0.96}
SPEEDUPS = {"gemv": 1.5, "dotp": 2.2, "axpy": 2.6}      # TROOP vs baseline

# Table II energy efficiencies (DP-GFLOPs/W) baseline -> TROOP
TABLE2 = {
    "dp-faxpy": (21.8, 27.5),
    "dp-fdotp": (25.9, 37.5),
    "dp-gemv": (48.0, 51.8),
    "dp-fmatmul": (61.1, 61.1),
}

# Table I area (kGE) — hardware-only; reproduced as a VMEM-footprint
# analogue (see table1_footprint.py).
TABLE1_AREA_RATIO = {"VLSU": 2.58, "VRF": 1.04, "Controller": 4.46,
                     "TCDM_XBAR": 1.78, "TOTAL": 1.07}

# Fig. 7 operational intensities (FLOPs per loaded element, 64-bit)
OI = {"axpy": 2 / 3, "dotp": 1.0, "gemv": 2.0, "fft": 2.5, "gemm": 16.0}
