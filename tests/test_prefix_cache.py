"""Chunked prefill + shared-prefix KV reuse: token identity vs the bucketed
engine, radix-index/refcount/COW mechanics, allocator leak freedom, int8
scale-page sharing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve import EngineConfig
from repro.serve.kvcache import (NULL_PAGE, BlockAllocator, PagedBackend,
                                 PrefixIndex)
from repro.serve.scheduler import Request, ServingEngine
from repro.serve.step import make_prefill_step, make_serve_step


def setup(**rt_kw):
    cfg = reduced(get_config("qwen1.5-0.5b"),
                  num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                  num_heads=2, num_kv_heads=2, head_dim=32)
    model = build_model(cfg, RuntimeConfig(remat="none", **rt_kw))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def make_engine(model, params, *, backend="paged", chunked=False,
                prefix=False, page_size=None, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("cache_len", 64)
    kw.setdefault("chunk_size", 8)
    if page_size is not None:
        assert backend == "paged"
        backend = PagedBackend(page_size=page_size)
    name = backend if isinstance(backend, str) else backend.name
    return ServingEngine(
        model, prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model), params=params, backend=backend,
        config=EngineConfig(backend=name, chunked_prefill=chunked,
                            prefix_cache=prefix, **kw))


def serve(eng, prompts, max_new=5, rid0=0):
    reqs = [Request(rid=rid0 + i, prompt=np.asarray(p, np.int32),
                    max_new_tokens=max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == len(reqs) and all(r.done for r in reqs)
    return {r.rid: r.out for r in reqs}


MIXED = [np.arange(1, 4 + 3 * i) % 63 + 1 for i in range(6)]


# --------------------------------------------------------------- tentpole
def test_chunked_matches_bucketed_mixed_lengths():
    """Chunked-prefill engine is token-identical to the PR 2 bucketed
    engine on a mixed-length trace, with exactly ONE prefill compile."""
    cfg, model, params = setup()
    outs = {}
    for chunked in (False, True):
        eng = make_engine(model, params, chunked=chunked, min_bucket=4)
        outs[chunked] = serve(eng, MIXED, max_new=6)
        if chunked:
            assert eng.prefill_traces == 1          # one slab shape, ever
            m = eng.metrics()
            assert m["chunk_calls"] >= len(MIXED)
            assert 0 < m["chunk_utilization"] <= 1
    assert outs[True] == outs[False]


def test_chunked_matches_bucketed_int8_kv():
    """Same identity under int8 KV pages: the bf16 chunk stage keeps later
    slabs from re-reading their own prompt through quantized pages."""
    cfg, model, params = setup(kv_cache_dtype="int8")
    outs = {}
    for chunked in (False, True):
        be = PagedBackend(page_size=32, kv_dtype="int8")
        eng = make_engine(model, params, backend=be, chunked=chunked,
                          min_bucket=4)
        outs[chunked] = serve(eng, MIXED, max_new=6)
    assert outs[True] == outs[False]


def test_chunked_matches_dense_oracle():
    """Greedy chunked output == full-forward greedy loop (dense oracle)."""
    cfg, model, params = setup()
    prompt = np.asarray([3, 14, 15, 9, 2, 6, 5, 35, 8, 9, 7, 9], np.int32)
    toks = list(prompt)
    for _ in range(4):
        logits, _ = model.train_logits(
            params, {"tokens": jnp.asarray([toks], jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    want = toks[len(prompt):]
    eng = make_engine(model, params, chunked=True, chunk_size=5)
    outs = serve(eng, [prompt, np.asarray([7, 7, 7], np.int32)], max_new=4)
    assert outs[0] == want


def test_long_prompt_does_not_block_running_decode():
    """The tentpole property: a running decode keeps emitting a token
    every cycle while a long prompt prefills slab by slab (the bucketed
    engine would stall it for the whole prompt)."""
    cfg, model, params = setup()
    eng = make_engine(model, params, chunked=True, chunk_size=4,
                      cache_len=64, slots=3)
    short = Request(rid=1, prompt=np.asarray([5, 6, 7], np.int32),
                    max_new_tokens=8)
    eng.submit(short)
    eng.step()                            # admitted, prefilled, decoding
    produced = len(short.out)
    assert produced >= 1
    long_req = Request(rid=0, prompt=np.arange(1, 41) % 63 + 1,
                       max_new_tokens=4)
    eng.submit(long_req)                  # 40 tokens -> 10 slabs
    for _ in range(3):
        eng.step()
        assert len(short.out) > produced  # decode advanced this cycle...
        produced = len(short.out)
        assert len(long_req.out) == 0     # ...while long is mid-prefill
    eng.run_until_drained()
    assert long_req.done and short.done


# ------------------------------------------------------------ prefix cache
def test_shared_prefix_token_identical_and_pages_shared():
    """Two requests sharing an N-page prefix: token-identical to unshared
    runs, and the prefix physically maps to the SAME pages."""
    cfg, model, params = setup()
    sysp = np.arange(1, 33) % 63 + 1                  # 32 = 2 pages @ 16
    prompts = [np.concatenate([sysp, [70 + i, 71, 72]]) for i in range(3)]
    eng = make_engine(model, params, chunked=True, prefix=True, slots=2)
    got = serve(eng, prompts)
    m = eng.metrics()
    assert m["prefix_hit_rate"] > 0
    assert m["prefix_hits"] >= 1
    eng2 = make_engine(model, params, chunked=True, prefix=False, slots=2)
    want = serve(eng2, prompts)
    assert got == want

    # physical sharing: admit two sharers simultaneously and compare tables
    eng3 = make_engine(model, params, chunked=True, prefix=True, slots=2)
    serve(eng3, prompts[:1])                          # seed the index
    r1 = Request(rid=10, prompt=np.asarray(prompts[1], np.int32),
                 max_new_tokens=8)
    r2 = Request(rid=11, prompt=np.asarray(prompts[2], np.int32),
                 max_new_tokens=8)
    eng3.submit(r1)
    eng3.submit(r2)
    eng3.step()
    bt = eng3.backend.block_tables
    live = [bt[s] for s, r in eng3.active.items() if r is not None]
    assert len(live) == 2
    assert list(live[0][:2]) == list(live[1][:2])     # same physical pages
    assert all(p != NULL_PAGE for p in live[0][:2])
    stats = eng3.backend.kv_page_bytes()
    assert stats["kv_pages_resident"] < stats["kv_pages_logical"]
    eng3.run_until_drained()


def test_cow_divergence_mid_page():
    """Prompts diverging mid-page copy the divergence page once (COW) and
    stay token-identical to an engine without the prefix cache."""
    cfg, model, params = setup()
    base = np.arange(1, 49) % 63 + 1                  # 48 tokens = 3 pages
    a = np.concatenate([base, [37, 2, 3]])
    b = base.copy()
    b[40] = 61                                        # diverge inside page 3
    b = np.concatenate([b, [4, 5, 6]])
    # pool roomy enough to keep a's pages cached while b admits
    eng = make_engine(model, params, chunked=True, prefix=True, slots=1,
                      backend=PagedBackend(page_size=16, num_pages=9,
                                           prefix_cache=True))
    got = serve(eng, [a, b])
    m = eng.metrics()
    assert m["cow_copies"] == 1                       # page 3 copied once
    assert m["prefix_shared_tokens"] == 40            # 2 full pages + 8 COW
    eng2 = make_engine(model, params, chunked=True, prefix=False, slots=1)
    assert got == serve(eng2, [a, b])


def test_allocator_leak_free_with_refcounts():
    """After drain + index clear, every page is back on the free list."""
    cfg, model, params = setup()
    sysp = np.arange(1, 33) % 63 + 1
    prompts = [np.concatenate([sysp, [70 + i, 71]]) for i in range(4)]
    eng = make_engine(model, params, chunked=True, prefix=True)
    serve(eng, prompts)
    be = eng.backend
    total = be.spec.num_pages - 1
    held = be.prefix_index.num_pages
    assert held > 0                                   # index keeps pages warm
    assert be.allocator.num_free == total - held      # slots released theirs
    for p, n in be.allocator._refs.items():
        assert n == 1, f"page {p} still has {n} refs after drain"
    be.prefix_index.clear()
    assert be.prefix_index.num_pages == 0
    assert be.allocator.num_free == total             # nothing leaked


def test_prefix_eviction_under_pool_pressure():
    """A pool too small for the index + a new request evicts cold prefix
    pages instead of deadlocking admission."""
    cfg, model, params = setup()
    eng = make_engine(model, params, chunked=True, prefix=True,
                      slots=1, cache_len=32,
                      backend=PagedBackend(page_size=16, num_pages=3,
                                           prefix_cache=True))
    serve(eng, [np.arange(1, 25) % 63 + 1])           # 1 full page, cached
    assert eng.backend.prefix_index.num_pages >= 1
    serve(eng, [np.arange(30, 54) % 63 + 1], rid0=5)  # disjoint: must evict
    assert eng.backend.prefix_index.evictions >= 1


def test_int8_scale_pages_shared_alongside_values():
    """int8 pools: a prefix hit shares value AND scale pages (one block
    table addresses both), and the engine still serves correctly."""
    cfg, model, params = setup(kv_cache_dtype="int8")
    be = PagedBackend(page_size=32, kv_dtype="int8")
    eng = make_engine(model, params, backend=be, chunked=True, prefix=True,
                      cache_len=96, chunk_size=16, slots=2)
    sysp = np.arange(1, 34) % 63 + 1                  # 33 toks: 1 full page
    prompts = [np.concatenate([sysp, [70 + i, 71, 72]]) for i in range(3)]
    got = serve(eng, prompts, max_new=4)
    m = eng.metrics()
    assert m["prefix_hit_rate"] > 0
    assert all(len(o) == 4 for o in got.values())
    # the shared page's scale rows are the same physical rows: the pool
    # leaf carries scale pages addressed by the identical table entry
    leaf = jax.tree.leaves(
        eng.caches, is_leaf=lambda x: getattr(x, "quantized", False))[0]
    assert leaf.quantized and leaf.k_scale_pool.shape[:2] \
        == leaf.k_pool.shape[:2]


# ----------------------------------------------------------- mechanics
def test_block_allocator_refcounts():
    a = BlockAllocator(6)                             # pages 1..5 usable
    got = a.alloc(2)
    a.incref([got[0]])
    a.free(got)                                       # got[0] survives
    assert a.num_free == 4 and a.ref(got[0]) == 1
    a.free([got[0]])
    assert a.num_free == 5 and a.ref(got[0]) == 0
    with pytest.raises(AssertionError):
        a.free([got[0]])                              # double free


def test_prefix_index_match_insert_partial():
    a = BlockAllocator(10)
    idx = PrefixIndex(4, a)
    pages = a.alloc(3)
    prompt = list(range(12)) + [99]                   # 3 full pages + 1
    idx.insert(prompt, pages)
    assert idx.num_pages == 3 and all(a.ref(p) == 2 for p in pages)
    full, partial = idx.match(list(range(12)) + [50, 51])
    assert full == pages and partial is None
    # divergence mid-page 2: tokens 0..5 match, 6 diverges
    full, partial = idx.match([0, 1, 2, 3, 4, 5, 77, 78, 79])
    assert full == pages[:1] and partial == (pages[1], 2)
    # the final token is never served from cache: an exact-prefix prompt
    # still leaves >= 1 token to compute
    full, partial = idx.match(list(range(12)))
    assert full == pages[:2] and partial == (pages[2], 3)


def test_prefix_index_eviction_is_lru_leaf_first():
    a = BlockAllocator(10)
    idx = PrefixIndex(2, a)
    p1 = a.alloc(2)
    p2 = a.alloc(1)
    idx.insert([0, 1, 2, 3, 9], p1)                  # chain of 2
    idx.insert([0, 1, 7, 8, 9], [p1[0], p2[0]])      # shares the root page
    a.free(p1)
    a.free(p2)                                       # index holds all refs
    idx.match([0, 1, 7, 8, 5])                       # touch the p2 branch
    assert idx.evict(1) == 1                         # LRU leaf: p1's tail
    assert a.ref(p1[1]) == 0 and a.ref(p2[0]) == 1
    assert idx.evict(5) == 2                         # rest drains leaf-first
    assert a.num_free == 9


def test_chunked_rejects_unsupported_archs():
    cfg = reduced(get_config("rwkv6-3b"))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="causal-attention"):
        make_engine(model, params, chunked=True)
    cfg2, model2, params2 = setup()
    with pytest.raises(ValueError, match="paged"):
        make_engine(model2, params2, backend="dense", chunked=True)
    with pytest.raises(ValueError, match="chunked_prefill"):
        make_engine(model2, params2, chunked=False, prefix=True)


def test_first_token_finish_rules_match_across_engines():
    """stop_token hit (or max_new_tokens == 1) on the prefill-emitted
    first token finishes the request identically in the bucketed and
    chunked engines — neither may emit a token past the stop."""
    cfg, model, params = setup()
    prompt = np.asarray([3, 14, 15, 9, 2, 6], np.int32)
    logits, _ = model.train_logits(
        params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    first = int(jnp.argmax(logits[0, -1]))
    for chunked in (False, True):
        # first greedy token IS the stop token
        eng = make_engine(model, params, chunked=chunked, stop_token=first)
        outs = serve(eng, [prompt], max_new=6)
        assert outs[0] == [first], (chunked, outs)
        # max_new_tokens=1: exactly one token, from prefill alone
        eng = make_engine(model, params, chunked=chunked)
        outs = serve(eng, [prompt], max_new=1)
        assert outs[0] == [first], (chunked, outs)


def test_chunk_kernel_path_matches_jnp():
    """RuntimeConfig(paged_kernel_decode=True) routes chunk attention
    through the Pallas ``prefill_attention_paged`` kernel; slab logits
    match the jnp gather path mid-prefill (query offset > 0)."""
    cfg, model, params = setup()
    from repro.models import build_model as bm
    kmodel = bm(cfg, RuntimeConfig(remat="none", paged_kernel_decode=True))
    eng = make_engine(model, params, chunked=True, chunk_size=8, slots=2)
    eng.submit(Request(rid=0, prompt=np.arange(1, 20) % 63 + 1,
                       max_new_tokens=2))
    eng.step()                               # slab 1 done, mid-prefill
    slot = eng._prefilling[0]
    req = eng.active[slot]
    off = eng._chunk_off[slot]
    assert off > 0
    C = eng.chunk_size
    valid = min(off + C, req.prompt_len) - off
    tokens = np.zeros((1, C), np.int32)
    tokens[0, :valid] = req.prompt[off:off + valid]
    batch = {"tokens": jnp.asarray(tokens),
             "offset": jnp.asarray([off], jnp.int32),
             "valid": jnp.asarray([valid], jnp.int32),
             "stage_base": jnp.asarray([0], jnp.int32),
             "block_tables": jnp.asarray(
                 eng.backend.block_tables[slot:slot + 1])}
    lj, _ = model.chunk_step(params, batch, eng.caches)
    lk, _ = kmodel.chunk_step(params, batch, eng.caches)
    np.testing.assert_allclose(np.asarray(lk, np.float32),
                               np.asarray(lj, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_per_request_latency_metrics():
    """run_until_drained exposes per-request TTFT + decode tok/s (the
    ci_gate / serve_bench inputs), not just aggregate steps/s."""
    cfg, model, params = setup()
    eng = make_engine(model, params, chunked=True)
    reqs = [Request(rid=i, prompt=np.asarray([5, 6, 7 + i], np.int32),
                    max_new_tokens=5) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_drained()
    assert len(finished) == 2
    for r in finished:
        assert r.ttft_s > 0 and r.finish_t >= r.first_token_t
        assert r.decode_tok_s > 0
    m = eng.metrics()
    assert m["ttft_s_mean"] > 0 and m["ttft_s_p95"] >= m["ttft_s_mean"] * 0.5
    assert m["decode_tok_s_mean"] > 0
