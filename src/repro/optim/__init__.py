from repro.optim.adamw import OptConfig, make_optimizer

__all__ = ["OptConfig", "make_optimizer"]
