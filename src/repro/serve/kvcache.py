"""Paged KV-cache subsystem: page pool + block tables behind ``CacheBackend``.

The paper's decode workload streams the KV cache at OI~=1; every wasted byte
moves the roofline bound itself.  A dense per-slot cache of capacity S wastes
``(S - len) / S`` of its traffic-eligible bytes on padding.  This module
stores KV in fixed-size *pages* (a shared pool per layer) with per-slot
*block tables* mapping logical block -> physical page — the software analog
of TROOP mechanisms (D)/(E): pages are hardware-aligned layout granules
(``core.troop.sublane``), physically disjoint by construction, so the
decoupled streams of the paged decode kernel read conflict-free contiguous
regions regardless of how slots come and go.

Two backends implement one protocol:

  * ``DenseBackend``  — the original layout: per-slot dense caches,
    admission splices prefill rows with pad + dynamic_update_slice.
  * ``PagedBackend``  — page pool + host-side ``BlockAllocator``; admission
    scatters prefill KV into freshly allocated pages and frees them when the
    request finishes (no splicing, no padding traffic).

Pages are *refcounted*: the chunked-prefill engine shares common prompt
prefixes (system prompts, few-shot headers) across slots through a radix
``PrefixIndex`` over page-granular token runs — a matched prefix maps to
existing physical pages (incref, zero recompute, zero extra HBM), and a
prompt diverging *mid-page* copies the divergence page once (copy-on-write)
before overwriting its tail.  Shared pages are read-only by invariant: the
engine only ever writes rows at positions >= its prefill offset, which by
construction land in freshly allocated (or COW-copied) pages.

The engine (``serve.scheduler``) talks only to the protocol; the model
(``models.attention``) recognizes ``PagedKVCache`` leaves and routes decode
reads/writes through the block table it receives in the step batch.

Kept import-light on purpose: no top-level ``repro.models`` import (models
import this module for the ``PagedKVCache`` leaf type).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.troop import sublane

NULL_PAGE = 0          # page 0 is never allocated: idle slots point here


class PagedKVCache(NamedTuple):
    """Paged KV leaf: page pools, indexed by a per-slot block table.

    ``k_pool``/``v_pool``: (P, page, KV, hd) — or (L, P, page, KV, hd) when
    the layer group is stacked for ``lax.scan``.  The block table is *not*
    part of the leaf: it is per-step input (``batch["block_tables"]``), while
    the pools are per-step state — one table addresses every layer's pool.

    ``kv_dtype="int8"`` pools carry *scale pages* alongside: per-(token,
    head) absmax scales, (P, page, KV, 1), addressed by the SAME block
    table — the allocator/free list never knows they exist.
    """
    k_pool: jax.Array
    v_pool: jax.Array
    k_scale_pool: Optional[jax.Array] = None   # (.., P, page, KV, 1) if int8
    v_scale_pool: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale_pool is not None

    @property
    def page_size(self) -> int:
        return self.k_pool.shape[-3]

    @property
    def num_pages(self) -> int:
        return self.k_pool.shape[-4]


class ChunkStage(NamedTuple):
    """bf16 staging rows for the one in-flight chunked-prefill slot.

    Only allocated when the page pools are quantized: chunk c of a prompt
    attends over the KV of chunks < c, and reading those rows back through
    int8 pages would make chunked prefill numerically diverge from the
    bucketed engine (which runs the whole prompt in bf16 and quantizes only
    at storage).  The stage keeps the *current request's own* prefill rows
    at full precision — `(1, S, KV, hd)`, one slot's worth — while the int8
    pages written alongside stay the decode-time source of truth.  bf16
    pools skip the stage entirely (pages already hold exact bf16 rows).
    """
    k: jax.Array       # (1, S, KV, hd) bf16
    v: jax.Array


@dataclass(frozen=True)
class PageSpec:
    """Static paging geometry for one engine."""
    page_size: int            # tokens per page (a troop layout granule)
    num_pages: int            # physical pages per layer pool (incl. null)
    blocks_per_slot: int      # logical blocks per slot (= ceil(S / page))
    kv_dtype: str = "bfloat16"  # page-pool storage ("int8" adds scale pages)

    def validate(self):
        g = sublane(self.kv_dtype)
        assert self.page_size % g == 0, \
            f"page_size {self.page_size} not a multiple of the " \
            f"{g}-row layout granule for {self.kv_dtype} (mechanism D)"
        assert self.num_pages > NULL_PAGE + 1
        return self

    @staticmethod
    def for_engine(slots: int, cache_len: int, page_size: int,
                   num_pages: Optional[int] = None,
                   dtype="bfloat16") -> "PageSpec":
        blocks = -(-cache_len // page_size)
        pages = num_pages if num_pages is not None else slots * blocks + 1
        return PageSpec(page_size, pages, blocks,
                        jnp.dtype(dtype).name).validate()


class BlockAllocator:
    """Host-side refcounted free list over physical pages [1, num_pages).

    ``alloc`` hands out pages at refcount 1; ``incref`` adds holders
    (another slot sharing the page, or the prefix index keeping it warm);
    ``free`` decrefs and returns a page to the free list only when its last
    holder lets go.  The original alloc/free discipline (every page held by
    exactly one slot) is the refcount-1 special case.

    With a ``tracer``, emits ``page_alloc`` / ``page_free`` instants on the
    allocator track — ``page_free`` counts pages *actually returned* to the
    free list (a decref of a shared page is not a free), so at any moment
    ``sum(page_alloc.pages) - sum(page_free.pages) == pages in use``.
    """

    def __init__(self, num_pages: int, tracer=None):
        self.num_pages = num_pages
        self.tracer = tracer
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._refs: Dict[int, int] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    def ref(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        if self.tracer is not None and n:
            self.tracer.instant("page_alloc", "allocator", pages=n)
            self.tracer.counter("pages_in_use",
                                self.num_pages - 1 - len(self._free))
        return pages

    def incref(self, pages: List[int]):
        for p in pages:
            assert self._refs.get(p, 0) > 0, f"incref of unheld page {p}"
            self._refs[p] += 1

    def free(self, pages: List[int]):
        returned = 0
        for p in pages:
            assert p != NULL_PAGE
            n = self._refs.get(p, 0)
            assert n > 0, f"double free of page {p}"
            if n == 1:
                del self._refs[p]
                self._free.append(p)
                returned += 1
            else:
                self._refs[p] = n - 1
        if self.tracer is not None and returned:
            self.tracer.instant("page_free", "allocator", pages=returned)
            self.tracer.counter("pages_in_use",
                                self.num_pages - 1 - len(self._free))


class _PrefixNode:
    """One cached page: ``tokens`` (page_size-tuple) -> physical ``page``."""

    __slots__ = ("tokens", "page", "children", "last_used")

    def __init__(self, tokens, page):
        self.tokens = tokens
        self.page = page
        self.children: Dict[tuple, "_PrefixNode"] = {}
        self.last_used = 0


class PrefixIndex:
    """Radix tree over page-granular token prefixes -> physical pages.

    Each node is one full page of prompt tokens; a path from the root spells
    a prompt prefix and yields the refcounted pages holding its KV.  The
    index itself holds one reference on every cached page (taken at
    ``insert``, dropped at eviction), so pages survive their originating
    request and are evicted LRU-leaf-first only under pool pressure.

    ``match`` returns the longest run of fully matched pages plus, when the
    next page agrees on a strict prefix of its tokens, a *partial* match
    ``(page, depth)`` — the copy-on-write divergence page.
    """

    def __init__(self, page_size: int, allocator: BlockAllocator):
        self.page_size = page_size
        self.allocator = allocator
        self.root = _PrefixNode((), NULL_PAGE)
        self._clock = 0
        self._nodes = 0
        self.hits = 0
        self.lookups = 0
        self.evictions = 0

    @property
    def num_pages(self) -> int:
        return self._nodes

    def _pages(self, prompt) -> List[tuple]:
        ps = self.page_size
        toks = [int(t) for t in prompt]
        return [tuple(toks[i:i + ps]) for i in range(0, len(toks) - ps + 1,
                                                     ps)]

    def match(self, prompt):
        """Longest cached prefix of ``prompt``: (pages, partial).

        ``pages``: physical pages of fully matched leading pages (NOT yet
        incref'd — the caller takes its references).  ``partial``: `(page,
        depth)` when the first unmatched page shares its leading ``depth``
        tokens with a cached page (0 < depth < page_size) — the COW
        candidate — else ``None``.  The match is capped so at least the
        prompt's final token is always left to compute (prefill must
        produce next-token logits).
        """
        self.lookups += 1
        self._clock += 1
        ps = self.page_size
        limit = len(prompt) - 1            # tokens allowed to come from cache
        node, pages, depth = self.root, [], 0
        for key in self._pages(prompt):
            if depth + ps > limit:
                break
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = self._clock
            pages.append(child.page)
            node, depth = child, depth + ps
        partial = None
        rest = [int(t) for t in prompt[depth:limit]]
        if rest:
            best = 0
            for key, child in node.children.items():
                j = 0
                while j < len(rest) and j < ps and key[j] == rest[j]:
                    j += 1
                if j > best:
                    best, partial = j, (child.page, j)
                    child.last_used = self._clock
        if pages or partial:
            self.hits += 1
        return pages, partial

    def insert(self, prompt, pages: List[int]):
        """Register ``prompt``'s leading full pages (physical ids ``pages``,
        one per full page) — the index increfs each page it newly adopts;
        pages whose token run is already cached are left alone."""
        self._clock += 1
        node = self.root
        for key, page in zip(self._pages(prompt), pages):
            child = node.children.get(key)
            if child is None:
                child = _PrefixNode(key, page)
                node.children[key] = child
                self.allocator.incref([page])
                self._nodes += 1
            child.last_used = self._clock
            node = child

    def evict(self, need: int) -> int:
        """Drop LRU leaf pages (held only by the index, refcount 1) until
        ``need`` pages have been freed or nothing more is evictable.

        Each pass collects every evictable leaf in one tree walk and frees
        them oldest-first (O(nodes log nodes) per pass, not one full walk
        per page); freeing a leaf may expose its parent, so passes repeat
        until sated or a pass frees nothing."""
        freed = 0
        while freed < need:
            victims = []                  # (last_used, parent, key, node)
            stack = [self.root]
            while stack:
                node = stack.pop()
                for key, child in node.children.items():
                    if not child.children \
                            and self.allocator.ref(child.page) == 1:
                        victims.append((child.last_used, node, key, child))
                    stack.append(child)
            if not victims:
                break
            victims.sort(key=lambda v: v[0])
            for _, parent, key, child in victims[:need - freed]:
                del parent.children[key]
                self.allocator.free([child.page])
                self._nodes -= 1
                self.evictions += 1
                freed += 1
        return freed

    def clear(self):
        """Drop every index-held reference (leaves first, repeatedly)."""
        while self._nodes and self.evict(self._nodes):
            pass


# --------------------------------------------------------------------------
# Tree splicing helpers (shared by both backends)
# --------------------------------------------------------------------------
def _batch_dim(dst_shape, src_shape, slots):
    """Batch dim for a B=1 splice: where dst == slots and src == 1 (prefer
    dim 1: stacked layer caches are (layers, B, ...))."""
    for d in (1, 0):
        if len(dst_shape) > d and dst_shape[d] == slots \
                and src_shape[d] == 1:
            return d
    raise ValueError(f"cannot locate batch dim: {dst_shape} vs {src_shape}")


def splice_row(dst, src, row: int, slot: int, slots: int,
               axis: Optional[int] = None):
    """Insert row ``row`` of a batched prefill array into slot ``slot`` of a
    batch-cache array, padding trailing (sequence) dims up to dst size.

    ``axis`` is the leaf's slot axis (from ``slot_axes`` — exact, no shape
    guessing); without it, fall back to the B=1 heuristic (compat shim).
    """
    bi = _batch_dim(dst.shape, src.shape, slots) if axis is None else axis
    if bi < 0:
        return dst                 # slot-independent leaf (shared pool)
    src = jax.lax.index_in_dim(src, row, axis=bi, keepdims=True)
    src = src.astype(dst.dtype)
    pads = []
    for d in range(src.ndim):
        tgt = 1 if d == bi else dst.shape[d]
        pads.append((0, tgt - src.shape[d]))
    src = jnp.pad(src, pads)
    start = [0] * dst.ndim
    start[bi] = slot
    return jax.lax.dynamic_update_slice(dst, src, tuple(start))


def slot_axes(model, slots: int, cache_len: int, page_spec=None,
              chunk_stage: int = 0):
    """Per-leaf slot axis of the cache tree, derived structurally: diff the
    ``eval_shape`` of ``init_caches`` at two slot counts — the axis whose
    extent changes is the slot axis (-1: slot-independent, e.g. a shared
    page pool).  No allocation, no shape heuristics — a state leaf whose
    head/seq extent happens to equal ``slots`` cannot be misidentified."""
    a = jax.eval_shape(
        lambda: model.init_caches(slots, cache_len, page_spec=page_spec,
                                  chunk_stage=chunk_stage))
    b = jax.eval_shape(
        lambda: model.init_caches(slots + 1, cache_len, page_spec=page_spec,
                                  chunk_stage=chunk_stage))

    def axis(x, y):
        for d, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return d
        return -1

    return jax.tree.map(axis, a, b)


def _is_paged(x) -> bool:
    return isinstance(x, PagedKVCache)


def _pool_scatter(pool, rows, pages: List[int]):
    """Write prefill KV rows into allocated pages of one pool leaf.

    pool: (P, page, KV, hd) or (L, P, page, KV, hd) when the layer group is
    stacked; rows: (T, KV, hd) / (L, T, KV, hd) correspondingly — padded or
    truncated to exactly fill the pages.
    """
    stacked = pool.ndim == 5
    t_axis = 1 if stacked else 0
    page = pool.shape[t_axis + 1]
    need = len(pages) * page
    T = rows.shape[t_axis]
    if T < need:
        pads = [(0, 0)] * rows.ndim
        pads[t_axis] = (0, need - T)
        rows = jnp.pad(rows, pads)
    elif T > need:
        rows = jax.lax.slice_in_dim(rows, 0, need, axis=t_axis)
    shp = (rows.shape[:t_axis] + (len(pages), page) + rows.shape[t_axis + 1:])
    buf = rows.reshape(shp).astype(pool.dtype)
    idx = jnp.asarray(pages, jnp.int32)
    if stacked:
        return pool.at[:, idx].set(buf)
    return pool.at[idx].set(buf)


def copy_page(caches, src, dst):
    """Copy physical page ``src`` -> ``dst`` in every paged leaf (the COW
    copy at a mid-page prefix divergence).  ``src``/``dst`` are int32
    scalars so the jitted copy compiles once; scale pages of int8 pools ride
    along — a COW'd page keeps value and scale rows coherent by
    construction (they share the index)."""
    def one(leaf):
        if not _is_paged(leaf):
            return leaf

        def cp(pool):
            if pool is None:
                return None
            if pool.ndim == 5:                  # stacked layer group
                return pool.at[:, dst].set(pool[:, src])
            return pool.at[dst].set(pool[src])

        return PagedKVCache(cp(leaf.k_pool), cp(leaf.v_pool),
                            cp(leaf.k_scale_pool), cp(leaf.v_scale_pool))
    return jax.tree.map(one, caches, is_leaf=_is_paged)


def kv_row_bytes(cfg, kv_dtype: str) -> int:
    """Bytes one token-row of KV occupies across all attention layers —
    the unit of the serve layer's streamed-bytes model (decode reads every
    cached row once per step).  ``int8`` rows carry the per-(token, head)
    bf16 absmax scales alongside (quantize_kv layout)."""
    n_attn = sum(1 for (m, _) in cfg.layer_kinds() if m == "attn")
    if kv_dtype == "int8":
        per_layer = cfg.num_kv_heads * cfg.head_dim * 1 * 2   # K + V bytes
        per_layer += cfg.num_kv_heads * 2 * 2                 # bf16 scales
    else:
        itemsize = jnp.dtype(kv_dtype).itemsize
        per_layer = cfg.num_kv_heads * cfg.head_dim * itemsize * 2
    return n_attn * per_layer


def resolve_kv_dtype(model) -> str:
    """The ONE resolver of the KV-storage dtype (DESIGN.md §10).

    A model's KV rows are stored as ``rt.kv_dtype()`` — ``kv_cache_dtype``
    when set, else ``cache_dtype`` — normalized to a dtype name, falling
    back to the model compute dtype when the model carries no
    ``RuntimeConfig``.  Resolved ONCE at engine construction and passed
    down; the per-call-site ``or``-fallbacks that used to re-derive it
    (and silently disagreed for ``cache_dtype="int8"`` under the paged
    backend) are gone.
    """
    rt = getattr(model, "rt", None)
    if rt is not None:
        kd = rt.kv_dtype()
        return "int8" if kd == "int8" else jnp.dtype(kd).name
    return jnp.dtype(model.cfg.dtype).name


# --------------------------------------------------------------------------
# Backends
# --------------------------------------------------------------------------
class CacheBackend(Protocol):
    """What the serving engine needs from a cache layout."""

    name: str

    def init_caches(self, model, slots: int, cache_len: int): ...

    def check_admissible(self, tokens: int):
        """Raise if a request needing ``tokens`` rows can NEVER be admitted
        (backpressure must not degenerate into a silent drop)."""
        ...

    def reserve(self, slot: int, tokens: int) -> bool:
        """Claim capacity for ``tokens`` total rows in ``slot``; False if
        the backing store is exhausted (engine defers admission)."""
        ...

    def admit(self, caches, prefill_caches, *, row: int, slot: int,
              prompt_len: int):
        """Move row ``row`` of a batched-prefill cache into ``slot``."""
        ...

    def release(self, slot: int):
        """Return ``slot``'s capacity to the pool (request finished)."""
        ...

    def batch_extras(self) -> Dict[str, Any]:
        """Extra decode-batch entries (e.g. the block table)."""
        ...

    def stats(self) -> Dict[str, Any]: ...


class DenseBackend:
    """The original layout: per-slot dense caches of capacity ``cache_len``."""

    name = "dense"

    def __init__(self):
        self.slots = 0
        self.tracer = None         # set by the engine (repro.obs.Tracer)

    def init_caches(self, model, slots: int, cache_len: int):
        self.slots = slots
        self.cache_len = cache_len
        self._axes = slot_axes(model, slots, cache_len)
        return model.init_caches(slots, cache_len)

    def check_admissible(self, tokens: int):
        pass

    def reserve(self, slot: int, tokens: int) -> bool:
        return True

    def admit(self, caches, prefill_caches, *, row: int, slot: int,
              prompt_len: int):
        return jax.tree.map(
            lambda dst, src, ax: splice_row(dst, src, row, slot, self.slots,
                                            axis=ax),
            caches, prefill_caches, self._axes)

    def release(self, slot: int):
        pass

    def batch_extras(self) -> Dict[str, Any]:
        return {}

    def stats(self) -> Dict[str, Any]:
        return {"backend": self.name, "cache_tokens": self.slots *
                getattr(self, "cache_len", 0)}


class PagedBackend:
    """Page pool + block tables; pages are troop layout granules.

    ``num_pages=None`` sizes the pool for full occupancy (capacity parity
    with dense); smaller values overcommit HBM — admission then *defers*
    when the pool is exhausted instead of OOMing, exactly like a production
    engine under memory pressure.

    ``kv_dtype="int8"`` stores pages quantized (per-(token, head) absmax
    scales in sibling scale pages — same block table, same allocator; the
    free list never changes).  Left ``None`` it follows the model's
    ``RuntimeConfig.kv_cache_dtype`` so a quantized engine is one flag;
    note the int8 layout granule is coarser (pages must be multiples of 32
    rows, not 16 — ``PageSpec.validate``).
    """

    name = "paged"

    def __init__(self, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 kv_dtype: Optional[str] = None,
                 prefix_cache: bool = False,
                 chunk_stage: int = 0):
        """``chunk_stage``: the chunked engine's chunk SIZE in tokens (0 =
        no staging buffer) — it sizes the bf16 stage over int8 pools; the
        engine sets it from its own ``chunk_size``."""
        self.page_size = page_size
        self.num_pages = num_pages
        self.kv_dtype = kv_dtype
        self.prefix_cache = prefix_cache
        self.chunk_stage = chunk_stage
        self.tracer = None         # set by the engine (repro.obs.Tracer)
        self.spec: Optional[PageSpec] = None
        self.prefix_index: Optional[PrefixIndex] = None
        self._pending_cow: Dict[int, Any] = {}
        self._shared_tokens = 0
        self.cow_copies = 0
        # tensor-parallel layout, set by the engine: kv_shards > 1 means
        # the pools are head-sharded and each device holds 1/kv_shards of
        # every page; kv_shards == 1 under tp > 1 means replicated pools
        # (the GQA fallback when kv_heads < tp)
        self.tp = 1
        self.kv_shards = 1

    def init_caches(self, model, slots: int, cache_len: int):
        dtype = self.kv_dtype or resolve_kv_dtype(model)
        self.kv_dtype = dtype          # resolved once, readable ever after
        self.slots = slots
        self.cache_len = cache_len
        self.spec = PageSpec.for_engine(slots, cache_len, self.page_size,
                                        self.num_pages, dtype)
        self.allocator = BlockAllocator(self.spec.num_pages,
                                        tracer=self.tracer)
        self.block_tables = np.full(
            (slots, self.spec.blocks_per_slot), NULL_PAGE, np.int32)
        self._slot_pages: Dict[int, List[int]] = {}
        if self.prefix_cache:
            self.prefix_index = PrefixIndex(self.spec.page_size,
                                            self.allocator)
        self._axes = slot_axes(model, slots, cache_len, page_spec=self.spec,
                               chunk_stage=self.chunk_stage)
        self._row_bytes = kv_row_bytes(model.cfg, dtype)
        return model.init_caches(slots, cache_len, page_spec=self.spec,
                                 chunk_stage=self.chunk_stage)

    def _pages_needed(self, tokens: int) -> int:
        return -(-min(tokens, self.cache_len) // self.spec.page_size)

    def check_admissible(self, tokens: int):
        """Raised at submit time — before anything is popped or reserved —
        so an impossible request never strands queue entries or pages."""
        need = self._pages_needed(tokens)
        if need > self.spec.num_pages - 1:
            raise ValueError(
                f"request needs {need} pages but the pool holds "
                f"{self.spec.num_pages - 1}: it can never be admitted — "
                f"raise num_pages or lower prompt_len + max_new_tokens")

    def _alloc_evicting(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages, evicting cold prefix-index pages to make
        room (shared pages held by live slots are never evicted — eviction
        only touches pages whose sole holder is the index)."""
        pages = self.allocator.alloc(n)
        if pages is None and self.prefix_index is not None:
            freed = self.prefix_index.evict(n - self.allocator.num_free)
            if freed and self.tracer is not None:
                self.tracer.instant("evict", "allocator", pages=freed)
            pages = self.allocator.alloc(n)
        return pages

    def reserve(self, slot: int, tokens: int) -> bool:
        pages = self._alloc_evicting(self._pages_needed(tokens))
        if pages is None:
            return False
        self._slot_pages[slot] = pages
        self.block_tables[slot] = NULL_PAGE
        self.block_tables[slot, :len(pages)] = pages
        return True

    def extend(self, slot: int, tokens: int) -> int:
        """Grow ``slot``'s page run to cover ``tokens`` rows (the
        speculative lookahead window past the baseline reservation).
        Returns the rows actually covered — all-or-nothing per allocation,
        so under pool pressure the run stays as-is and the caller clamps
        its window to what's covered (never below the baseline, so
        speculation degrades to plain decode instead of deadlocking)."""
        have = self._slot_pages.setdefault(slot, [])
        need = self._pages_needed(tokens) - len(have)
        if need > 0:
            fresh = self._alloc_evicting(need)
            if fresh is not None:
                self.block_tables[slot, len(have):len(have) + len(fresh)] \
                    = fresh
                have.extend(fresh)
        return min(len(have) * self.spec.page_size, self.cache_len)

    def rollback(self, slot: int, tokens: int) -> int:
        """Rewind ``slot`` to ``tokens`` rows: free every page past
        ``ceil(tokens / page_size)`` and NULL its table entry — the
        rejected speculative suffix.  Trailing pages are private by
        construction (shared prefix pages sit at the FRONT of the run and
        a rollback target always covers the whole prompt), and int8 scale
        pages share the block table, so freeing the index frees both.
        Returns the number of pages freed."""
        pages = self._slot_pages.get(slot)
        if not pages:
            return 0
        keep = self._pages_needed(max(tokens, 1))
        tail = pages[keep:]
        if not tail:
            return 0
        del pages[keep:]
        self.block_tables[slot, keep:keep + len(tail)] = NULL_PAGE
        self.allocator.free(tail)
        return len(tail)

    def reserve_with_prefix(self, slot: int, tokens: int,
                            prompt) -> Optional[int]:
        """Reserve ``slot`` reusing cached prefix pages of ``prompt``.

        Returns the number of prompt tokens whose KV comes from the cache
        (the chunked engine starts prefilling at that offset), or ``None``
        when the pool is exhausted (admission defers).  A mid-page partial
        match registers a pending copy-on-write: the engine must apply it
        (``take_cow`` / ``cow_done``) before writing the slot's pages.
        """
        if self.prefix_index is None:
            return 0 if self.reserve(slot, tokens) else None
        page = self.spec.page_size
        shared, partial = self.prefix_index.match(prompt)
        # take the slot's references before any eviction can run: a page
        # referenced here is unevictable for the lifetime of the slot
        self.allocator.incref(shared)
        cow_src, cow_depth = partial if partial else (None, 0)
        if cow_src is not None:
            self.allocator.incref([cow_src])
        fresh_n = self._pages_needed(tokens) - len(shared)
        fresh = self._alloc_evicting(fresh_n)
        if fresh is None:                       # pool pressure: undo, defer
            self.allocator.free(shared)
            if cow_src is not None:
                self.allocator.free([cow_src])
            return None
        if cow_src is not None:
            # divergence mid-page: the first fresh page becomes a private
            # copy of the matched page; rows [0, depth) are reused, the
            # tail is overwritten by this request's own prefill
            self._pending_cow[slot] = (cow_src, fresh[0])
        pages = shared + fresh
        self._slot_pages[slot] = pages
        self.block_tables[slot] = NULL_PAGE
        self.block_tables[slot, :len(pages)] = pages
        offset = len(shared) * page + cow_depth
        self._shared_tokens += offset
        if offset and self.tracer is not None:
            self.tracer.instant("prefix_hit", "allocator", slot=slot,
                                shared_pages=len(shared), tokens=offset,
                                cow=cow_src is not None)
        return offset

    def take_cow(self, slot: int):
        """Pending (src_page, dst_page) copy for ``slot``, or ``None``."""
        return self._pending_cow.get(slot)

    def cow_done(self, slot: int):
        """The engine copied the divergence page: drop the source ref."""
        src, dst = self._pending_cow.pop(slot)
        self.allocator.free([src])
        self.cow_copies += 1
        if self.tracer is not None:
            self.tracer.instant("cow_copy", "allocator", slot=slot,
                                src_page=src, dst_page=dst)

    def register_prefix(self, slot: int, prompt):
        """Index ``slot``'s fully written prompt pages for future reuse
        (called by the engine once the prompt's KV is entirely on-pool)."""
        if self.prefix_index is None:
            return
        page = self.spec.page_size
        full = len(prompt) // page
        if full:
            self.prefix_index.insert(prompt, self._slot_pages[slot][:full])

    def admit(self, caches, prefill_caches, *, row: int, slot: int,
              prompt_len: int):
        pages = self._slot_pages[slot]
        page = self.spec.page_size
        n_prefill = -(-prompt_len // page)

        def one(dst, src):
            if _is_paged(dst):
                # src is the dense prefill KVCache for this sublayer;
                # its batch axis is 0 (unstacked) or 1 (stacked layers)
                b_axis = 0 if dst.k_pool.ndim == 4 else 1

                def rows(a):
                    return jax.lax.index_in_dim(a, row, axis=b_axis,
                                                keepdims=False)

                use = pages[:n_prefill]
                if not dst.quantized:
                    return PagedKVCache(
                        _pool_scatter(dst.k_pool, rows(src.k), use),
                        _pool_scatter(dst.v_pool, rows(src.v), use))
                # int8 pools: scatter quantized rows + their scale rows.
                # An int8 *prefill* cache (rt.kv_cache_dtype == "int8")
                # already carries per-token scales — reuse them verbatim so
                # paged and dense int8 engines are numerically identical;
                # a bf16 prefill cache is quantized here, at admit.
                if getattr(src, "quantized", False):
                    k8, ks = rows(src.k), rows(src.k_scale)
                    v8, vs = rows(src.v), rows(src.v_scale)
                else:
                    from repro.quant.tensor import quantize_kv
                    k8, ks = quantize_kv(rows(src.k))
                    v8, vs = quantize_kv(rows(src.v))
                return PagedKVCache(
                    _pool_scatter(dst.k_pool, k8, use),
                    _pool_scatter(dst.v_pool, v8, use),
                    _pool_scatter(dst.k_scale_pool, ks, use),
                    _pool_scatter(dst.v_scale_pool, vs, use))
            return dst

        # paged leaves first (is_leaf stops recursion there), then the
        # remaining dense leaves (mamba/rwkv state, MLA, cross-attn KV,
        # int8 scales) take the dense splice path along their slot axis.
        caches = jax.tree.map(one, caches, prefill_caches, is_leaf=_is_paged)

        def dense(dst, src, ax):
            if _is_paged(dst):
                return dst
            return splice_row(dst, src, row, slot, self.slots, axis=ax)

        return jax.tree.map(dense, caches, prefill_caches, self._axes,
                            is_leaf=_is_paged)

    def release(self, slot: int):
        if slot in self._pending_cow:           # released before the copy
            src, _ = self._pending_cow.pop(slot)
            self.allocator.free([src])
        pages = self._slot_pages.pop(slot, None)
        if pages:
            self.allocator.free(pages)
        self.block_tables[slot] = NULL_PAGE

    def batch_extras(self) -> Dict[str, Any]:
        return {"block_tables": jnp.asarray(self.block_tables)}

    def kv_page_bytes(self) -> Dict[str, int]:
        """Logical vs resident KV traffic accounting: ``logical`` counts
        every block-table entry (what per-slot decode streams), ``resident``
        counts each *physical* page once — shared prefix pages land in HBM
        a single time no matter how many slots map them, and the bytes
        model of the serve layer must not double-count them."""
        sp = self.spec
        if sp is None:
            return {"kv_pages_logical": 0, "kv_pages_resident": 0}
        live = self.block_tables[self.block_tables != NULL_PAGE]
        page_bytes = sp.page_size * self._row_bytes
        logical_b = int(live.size) * page_bytes
        resident_b = int(np.unique(live).size) * page_bytes
        # per-device resident bytes: a head-sharded pool splits every page
        # 1/kv_shards per device — the headline stays the single-copy
        # footprint, never tp × it; a replicated pool (GQA fallback) really
        # does hold a full copy per device.
        shards = self.kv_shards if self.kv_shards > 1 else 1
        if shards > 1:
            per_device = [resident_b // shards] * shards
        else:
            per_device = [resident_b] * max(self.tp, 1)
        return {"kv_pages_logical": int(live.size),
                "kv_pages_resident": int(np.unique(live).size),
                "kv_page_bytes_logical": logical_b,
                "kv_page_bytes_resident": resident_b,
                "kv_page_bytes_per_device": per_device,
                "kv_shards": shards}

    def stats(self) -> Dict[str, Any]:
        sp = self.spec
        out = {
            "backend": self.name,
            "page_size": sp.page_size if sp else self.page_size,
            "num_pages": sp.num_pages if sp else self.num_pages,
            "kv_dtype": sp.kv_dtype if sp else self.kv_dtype,
            "pages_free": self.allocator.num_free if sp else None,
            "pages_in_use": (sp.num_pages - 1 - self.allocator.num_free)
            if sp else None,
        }
        out.update(self.kv_page_bytes())
        if self.prefix_index is not None:
            out.update({
                "prefix_lookups": self.prefix_index.lookups,
                "prefix_hits": self.prefix_index.hits,
                "prefix_pages_cached": self.prefix_index.num_pages,
                "prefix_evictions": self.prefix_index.evictions,
                "prefix_shared_tokens": self._shared_tokens,
                "cow_copies": self.cow_copies,
            })
        return out


def make_backend(backend) -> CacheBackend:
    """'dense' | 'paged' | an instance -> a CacheBackend instance."""
    if backend is None:
        return DenseBackend()
    if isinstance(backend, str):
        if backend == "dense":
            return DenseBackend()
        if backend == "paged":
            return PagedBackend()
        raise ValueError(f"unknown cache backend {backend!r}")
    return backend


def bucket_length(n: int, min_bucket: int = 8,
                  cap: Optional[int] = None) -> int:
    """Power-of-2 prefill bucket for a prompt of length ``n`` — one XLA
    prefill compile per bucket, ever (the recompile-free admission path)."""
    b = max(min_bucket, 1 << max(0, math.ceil(math.log2(max(n, 1)))))
    if cap is not None:
        b = min(b, cap)
    return b
