"""Persistent tuned-config cache + the ``get_tuned`` dispatch lookup.

Entries are keyed ``kernel|shapes/dtypes|backend`` and stored as JSON so
tuned configs survive across processes; an in-process LRU view keeps hot
lookups off the disk dict.  The cache path resolves, in order:

  1. an explicit ``path=`` argument,
  2. the ``REPRO_TUNE_CACHE`` environment variable,
  3. ``~/.cache/repro/tune_cache.json``.

``default_cache()`` returns a per-path singleton, so pointing
``REPRO_TUNE_CACHE`` somewhere else (tests, multi-machine runs) yields a
fresh instance without any global reset.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from collections import OrderedDict
from typing import Any, Dict, Optional

from repro.core.troop import TroopConfig
from repro.tune import registry

ENV_VAR = "REPRO_TUNE_CACHE"
LRU_CAPACITY = 256

_CFG_FIELDS = {f.name for f in dataclasses.fields(TroopConfig)}


def config_to_dict(cfg: TroopConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def config_from_dict(d: Dict[str, Any]) -> TroopConfig:
    # tolerate fields added/removed across versions of TroopConfig
    return TroopConfig(**{k: v for k, v in d.items() if k in _CFG_FIELDS})


def resolve_path(path: Optional[str] = None) -> str:
    if path:
        return os.path.abspath(os.path.expanduser(path))
    env = os.environ.get(ENV_VAR)
    if env:
        return os.path.abspath(os.path.expanduser(env))
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tune_cache.json")


class TuneCache:
    """JSON-backed store of tune results with an in-process LRU view."""

    def __init__(self, path: Optional[str] = None,
                 capacity: int = LRU_CAPACITY):
        self.path = resolve_path(path)
        self.capacity = capacity
        self._disk: Dict[str, Dict[str, Any]] = {}
        self._lru: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.load()

    def load(self) -> int:
        """(Re)read the JSON file; returns the number of entries loaded."""
        self._disk = {}
        self._lru.clear()
        try:
            with open(self.path) as f:
                data = json.load(f)
            if isinstance(data, dict):
                self._disk = {k: v for k, v in data.items()
                              if isinstance(v, dict)}
        except (OSError, ValueError):
            pass                          # missing or corrupt -> empty
        return len(self._disk)

    def save(self):
        """Merge-then-atomic-write: re-read the file and overlay our entries
        so concurrent tuning processes don't clobber each other's keys
        (last writer wins only on the *same* key); tmp file + rename keeps
        readers from ever seeing a torn JSON document."""
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        try:
            with open(self.path) as f:
                on_disk = json.load(f)
            if isinstance(on_disk, dict):
                self._disk = {**{k: v for k, v in on_disk.items()
                                 if isinstance(v, dict)}, **self._disk}
        except (OSError, ValueError):
            pass
        fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tune.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._disk, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        if key in self._lru:
            self._lru.move_to_end(key)
            self.hits += 1
            return self._lru[key]
        if key in self._disk:
            self._touch(key, self._disk[key])
            self.hits += 1
            return self._disk[key]
        self.misses += 1
        return None

    def put(self, key: str, entry: Dict[str, Any]):
        self._disk[key] = entry
        self._touch(key, entry)

    def _touch(self, key: str, entry: Dict[str, Any]):
        self._lru[key] = entry
        self._lru.move_to_end(key)
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)

    def clear(self):
        self._disk.clear()
        self._lru.clear()

    def __len__(self) -> int:
        return len(self._disk)

    def __contains__(self, key: str) -> bool:
        return key in self._disk


_instances: Dict[str, TuneCache] = {}


def default_cache(path: Optional[str] = None) -> TuneCache:
    p = resolve_path(path)
    if p not in _instances:
        _instances[p] = TuneCache(p)
    return _instances[p]


def get_tuned(name: str, *args, cache: Optional[TuneCache] = None,
              variant_kwargs: Optional[Dict[str, Any]] = None
              ) -> TroopConfig:
    """Dispatch lookup: cached best config for (kernel, shapes, backend,
    variant), else the kernel's heuristic default.  Args may be real arrays,
    tracers, or ``jax.ShapeDtypeStruct`` placeholders — only shapes/dtypes
    are read.  ``variant_kwargs`` contributes the spec's declared
    ``key_kwargs`` (e.g. flash_attention's ``causal``) to the key.
    """
    spec = registry.get(name)
    c = cache if cache is not None else default_cache()
    entry = c.get(spec.key(*args, kwargs=variant_kwargs))
    if entry is not None and "config" in entry:
        return config_from_dict(entry["config"])
    return spec.heuristic(*args)
