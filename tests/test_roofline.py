"""Roofline analyzer: HLO parsing + terms math on synthetic inputs."""
import jax
import jax.numpy as jnp

from repro.core import roofline as RL


HLO = """
  %ag.1 = bf16[8,128] all-gather(%x), replica_groups={}
  %ar.2 = f32[16,16] all-reduce(%y), to_apply=%add
  %rs.3 = f32[4,4] reduce-scatter(%z), to_apply=%add
  %a2a.4 = bf16[2,2] all-to-all(%w)
  %cp.5 = s32[10] collective-permute(%v)
  %ags = (bf16[8,128], bf16[64,128]) all-gather-start(%q)
  %agd = bf16[64,128] all-gather-done(%ags)
"""


def test_parse_collectives_kinds_and_bytes():
    st = RL.parse_collectives(HLO)
    assert st.count_by_kind["all-gather"] == 2          # sync + -start
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["all-reduce"] == 16 * 16 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 64
    # -done is not double counted
    assert st.count_by_kind.get("all-gather", 0) == 2
    # ring weights: AR counts 2x
    assert st.link_bytes > st.total_bytes


def test_convert_bytes_only_large():
    txt = """
      %convert.1 = f32[1024,1024] convert(%a)
      %convert.2 = f32[8] convert(%b)
    """
    b = RL.convert_bytes(txt)
    assert b == int(1024 * 1024 * 4 * 1.5)


def test_roofline_terms_dominance():
    t = RL.RooflineTerms(flops=197e12, bytes_accessed=819e9 * 2,
                         collective_link_bytes=50e9 * 0.5, chips=256,
                         model_flops=197e12 * 256 * 0.5)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 2.0) < 1e-9
    assert abs(t.t_collective - 0.5) < 1e-9
    assert t.dominant() == "memory"
    assert abs(t.useful_flops_ratio() - 0.5) < 1e-9


def test_model_flops_conventions():
    from repro.configs import SHAPES, get_config
    cfg = get_config("qwen1.5-0.5b")
    tr = RL.model_flops_for(cfg, SHAPES["train_4k"])
    de = RL.model_flops_for(cfg, SHAPES["decode_32k"])
    n = cfg.param_count(active_only=True)
    assert tr == 6.0 * n * 256 * 4096
    assert de == 2.0 * n * 128


def test_scan_body_counted_once_methodology():
    """The §Dry-run methodology premise: cost_analysis counts scan once."""
    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    c = RL.cost_analysis(jax.jit(scanned).lower(x, w).compile())["flops"]
    unroll = RL.cost_analysis(jax.jit(lambda x, w: x @ w[0] @ w[1]).lower(
        x, w).compile())["flops"]
    assert c < 2.5 * unroll / 2     # ~1 body, not 8
