"""Collective matmuls — the paper's decoupled-stream overlap at mesh level.

Inside a ``shard_map``: instead of `all-gather then matmul` (communication
fully serialized before compute), the all-gather variant walks a ring —
each step multiplies the operand shard currently held with the matching
rows of the weight while ``collective-permute`` rotates the shards, so
per-step compute overlaps per-step communication (the mesh analogue of
TROOP mechanism (A)/(B)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def allgather_matmul(x_local, w_full, axis_name: str):
    """x_local (..., K/n) — the K-shard this device holds; w_full (K, N)
    rows replicated (its columns may themselves be a shard: only the K
    extent must match ``n * K/n``).  Returns the full (..., N) product on
    every device; leading batch dims ride along."""
    n = jax.lax.psum(1, axis_name)            # concrete under shard_map
    idx = jax.lax.axis_index(axis_name)
    Kl = x_local.shape[-1]
    perm = [(i, (i - 1) % n) for i in range(n)]

    # statically unrolled ring: ppermute inside a fori_loop deadlocks the
    # multi-device CPU backend, and unrolling lets XLA overlap each step's
    # matmul with the next shard's transfer
    acc = jnp.zeros(x_local.shape[:-1] + (w_full.shape[-1],), jnp.float32)
    xs = x_local
    for t in range(n):
        src = (idx + t) % n                   # shard id currently held
        w_rows = jax.lax.dynamic_slice_in_dim(w_full, src * Kl, Kl, axis=0)
        acc = acc + xs.astype(jnp.float32) @ w_rows.astype(jnp.float32)
        if t < n - 1:
            xs = jax.lax.ppermute(xs, axis_name, perm)
    return acc.astype(x_local.dtype)


def reduce_scatter_matmul(x_local, w_local, axis_name: str):
    """x_local (..., K/n), w_local (K/n, N): per-device partial product,
    reduce-scattered over N -> each device returns its (..., N/n) tile."""
    partial = x_local.astype(jnp.float32) @ w_local.astype(jnp.float32)
    out = jax.lax.psum_scatter(partial, axis_name,
                               scatter_dimension=partial.ndim - 1,
                               tiled=True)
    return out.astype(x_local.dtype)
