"""Deterministic synthetic data pipeline: sharded, prefetched, checkpointable.

Produces a reproducible token stream (hash-seeded per (step, shard)) so any
restart from a checkpoint regenerates byte-identical batches — the property
the fault-tolerance tests assert.  Per-shard streams are disjoint by
construction (seed folds in the shard id).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0      # vision stub prefix
    frontend_dim: int = 0
    enc_frames: int = 0           # whisper stub frames
    prefetch: int = 2


class SyntheticLM:
    """Markov-ish synthetic LM stream: next-token structure so loss can fall."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        assert cfg.global_batch % num_shards == 0
        self.local_batch = cfg.global_batch // num_shards
        self.step = 0

    def state_dict(self):
        return {"step": self.step, "shard": self.shard,
                "num_shards": self.num_shards}

    def load_state_dict(self, st):
        assert st["num_shards"] == self.num_shards, "reshard via set_step"
        self.step = st["step"]

    def set_step(self, step: int):
        self.step = step

    def _rng(self, step):
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.shard)

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        rng = self._rng(step)
        B, T, V = self.local_batch, c.seq_len, c.vocab_size
        # structured stream: tokens follow t_{i+1} = (a*t_i + b) % Veff with
        # noise — learnable short-range structure.
        veff = min(V, 4096)
        a = 1 + 4 * rng.integers(1, 8)
        b = rng.integers(1, veff)
        t0 = rng.integers(0, veff, size=(B, 1))
        toks = [t0]
        for _ in range(T):
            nxt = (a * toks[-1] + b) % veff
            flip = rng.random((B, 1)) < 0.1
            nxt = np.where(flip, rng.integers(0, veff, size=(B, 1)), nxt)
            toks.append(nxt)
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        batch = {"tokens": seq[:, :T], "targets": seq[:, 1:T + 1]}
        if c.frontend_tokens:
            batch["frontend"] = rng.standard_normal(
                (B, c.frontend_tokens, c.frontend_dim)).astype(np.float32)
        if c.enc_frames:
            batch["frontend"] = rng.standard_normal(
                (B, c.enc_frames, c.frontend_dim)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b


class PrefetchIterator:
    """Background-thread prefetch (overlaps host datagen with device step)."""

    def __init__(self, source: SyntheticLM, device_put=None, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.device_put = device_put or (lambda b: b)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = self.source.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            try:
                self.q.put(self.device_put(batch), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                self.q.put(self.device_put(batch))

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass
