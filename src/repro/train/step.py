"""train_step / eval_step builders (pure functions of (params, opt_state, batch)).

Supports gradient accumulation (microbatching) and optional int8 gradient
compression with error feedback on the data-parallel reduction
(``repro.dist.compression``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import OptConfig, clip_by_global_norm, make_optimizer
from repro.train import losses as L


def make_loss_fn(model, rt):
    def loss_fn(params, batch):
        if rt.loss_chunk:
            # chunked xent: run the trunk, then chunk the readout
            from repro.models import transformer as T
            cfg = model.cfg
            if cfg.encoder_decoder:
                logits, aux = model.train_logits(params, batch)
                loss = L.softmax_xent(logits, batch["targets"])
            else:
                dtype = jnp.dtype(cfg.dtype)
                groups = T.plan_groups(cfg)
                x = T.embed_inputs(params, cfg, batch, dtype)
                B, Tl = x.shape[:2]
                positions = jnp.arange(Tl)[None, :]
                states = T._zero_states(cfg, groups, B, dtype)
                x, _, aux = T._run_groups(params["groups"], groups, cfg, rt,
                                          x, positions=positions,
                                          states=states, dtype=dtype)
                tgt = batch["targets"]
                if x.shape[1] != tgt.shape[1]:    # vision prefix: ignore
                    x = x[:, x.shape[1] - tgt.shape[1]:, :]
                loss = L.chunked_softmax_xent(
                    x, lambda xc: T.readout(params, cfg, xc, dtype), tgt,
                    rt.loss_chunk)
        else:
            logits, aux = model.train_logits(params, batch)
            tgt = batch["targets"]
            if logits.shape[1] != tgt.shape[1]:   # vision prefix: ignore
                logits = logits[:, logits.shape[1] - tgt.shape[1]:, :]
            loss = L.softmax_xent(logits, tgt)
        return loss + aux, {"xent": loss, "aux": aux}
    return loss_fn


def make_train_step(model, opt_cfg: OptConfig, *, microbatches: int = 1,
                    compression=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    opt = make_optimizer(opt_cfg)
    loss_fn = make_loss_fn(model, model.rt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0

        def mb(i):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // microbatches),
                    x.shape[0] // microbatches, 0), batch)

        def body(carry, i):
            loss_acc, grads_acc = carry
            (loss, aux), grads = grad_fn(params, mb(i))
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), aux

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), auxs = jax.lax.scan(
            body, (jnp.zeros(()), zeros), jnp.arange(microbatches))
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        aux = jax.tree.map(lambda a: a[-1], auxs)
        return loss * inv, aux, grads

    def train_step(params, opt_state, batch):
        loss, aux, grads = compute_grads(params, batch)
        if compression is not None:
            grads = compression(grads)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state, lr = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **aux}
        return params, opt_state, metrics

    return train_step, opt


def make_eval_step(model):
    loss_fn = make_loss_fn(model, model.rt)

    def eval_step(params, batch):
        loss, aux = loss_fn(params, batch)
        return {"loss": loss, **aux}
    return eval_step
