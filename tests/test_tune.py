"""Tune subsystem: registry round-trip, analytic pruning, cache
persistence across a save/load cycle, dispatch fallback, and the
no-re-timing guarantee on a cache hit (the tune_report acceptance check)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels as K  # populates the registry
from repro import tune
from repro.core.troop import TroopConfig
from repro.kernels import ref as R

ALL_KERNELS = ("gemv", "dotp", "axpy", "rmsnorm", "decode_attention",
               "paged_decode_attention", "flash_attention", "fused_adamw",
               "mamba_scan", "rwkv6",
               # repro.quant fused-dequant kernels (DESIGN.md §5)
               "qgemv", "batched_qgemv", "decode_attention_int8",
               "paged_decode_attention_int8",
               # MX microscaling kernels (DESIGN.md §11)
               "mx_qgemv", "batched_mx_qgemv", "mx_qgemv_swiglu",
               "grouped_expert_qgemv")


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    """Point the default cache at a fresh file (per-path singleton, so no
    global reset is needed)."""
    path = str(tmp_path / "tune_cache.json")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    return path


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def test_registry_has_every_kernel():
    assert set(ALL_KERNELS) <= set(tune.names())
    for name in ALL_KERNELS:
        spec = tune.REGISTRY[name]
        assert callable(spec.fn)
        assert callable(spec.flops) and callable(spec.bytes)
        assert spec.space, name
        assert spec.example is not None, name


def test_registry_cost_models_accept_shape_structs():
    for name in ALL_KERNELS:
        spec = tune.REGISTRY[name]
        args, _ = spec.example(small=True)
        structs = [jax.ShapeDtypeStruct(a.shape, a.dtype)
                   if hasattr(a, "shape") else a for a in args]
        assert spec.flops(*structs) > 0, name
        assert spec.bytes(*structs) > 0, name
        assert spec.key(*structs) == spec.key(*args), name


def test_registry_bytes_models_match_streamed_operands():
    """Registry-wide audit: every kernel's modeled HBM traffic equals the
    sum of nbytes of its declared streamed operands (quantized kernels must
    count scale-tensor traffic — the §Perf A4 bytes audit)."""
    from repro.tune.registry import operand_bytes
    for name in tune.names():
        spec = tune.REGISTRY[name]
        assert spec.streamed is not None, \
            f"{name}: declare streamed= so the bytes model is auditable"
        args, _ = spec.example(small=True)
        want = operand_bytes(spec.streamed(*args))
        assert spec.bytes(*args) == pytest.approx(want), \
            f"{name}: bytes model {spec.bytes(*args)} != streamed {want}"


def test_quantized_bytes_models_count_scale_traffic():
    """The int8 cost models charge for the scale tensors, not just the
    int8 values — and still come out well under the bf16 sibling."""
    spec8 = tune.REGISTRY["decode_attention_int8"]
    (q, k8, ks, v8, vs, ln), _ = spec8.example(small=True)
    b8 = spec8.bytes(q, k8, ks, v8, vs, ln)
    values_only = (2 * k8.size + q.size * 2 * 2)
    assert b8 == values_only + 2 * ks.size * 2     # + k/v scale streams
    bf = tune.REGISTRY["decode_attention"]
    (qb, kb, vb, lnb), _ = bf.example(small=True)
    assert b8 < 0.6 * bf.bytes(qb, kb, vb, lnb)


def test_registry_dispatch_matches_reference(tmp_cache):
    """Calling the public entry point WITHOUT a config routes through
    get_tuned and still computes the right answer."""
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 512), jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (512,), jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(K.gemv(w, x), np.float32),
                               np.asarray(R.gemv(w, x), np.float32),
                               rtol=3e-2, atol=3e-2)
    xs = jax.random.normal(jax.random.PRNGKey(2), (8, 256), jnp.bfloat16)
    s = jax.random.normal(jax.random.PRNGKey(3), (256,), jnp.float32)
    np.testing.assert_allclose(np.asarray(K.rmsnorm(xs, s), np.float32),
                               np.asarray(R.rmsnorm(xs, s), np.float32),
                               rtol=3e-2, atol=3e-2)


def test_explicit_config_bypasses_dispatch(tmp_cache):
    """Positional/keyword TroopConfig uses the raw kernel path (exact same
    numerics as spec.fn)."""
    spec = tune.REGISTRY["dotp"]
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (4096,), jnp.float32)
    cfg = TroopConfig(streams=1, unroll=1)
    np.testing.assert_array_equal(np.asarray(K.dotp(x, y, cfg)),
                                  np.asarray(spec.fn(x, y, cfg=cfg)))


# --------------------------------------------------------------------------
# search: enumeration + analytic prune
# --------------------------------------------------------------------------
def test_enumerate_space_validates_configs():
    for name in ALL_KERNELS:
        spec = tune.REGISTRY[name]
        cfgs = tune.enumerate_space(spec)
        assert cfgs, name
        for cfg in cfgs:
            cfg.validate()
        assert len(set(cfgs)) == len(cfgs), f"{name}: duplicate candidates"


@pytest.mark.parametrize("name", ["gemv", "dotp", "decode_attention"])
@pytest.mark.parametrize("keep", [1, 2, 4])
def test_prune_never_discards_predicted_best(name, keep):
    spec = tune.REGISTRY[name]
    args, _ = spec.example(small=True)
    structs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args
               if hasattr(a, "shape")]
    cands = [tune.Candidate(cfg, tune.predict_fraction(spec, cfg, *structs))
             for cfg in tune.enumerate_space(spec)]
    best = max(cands, key=lambda c: c.predicted)
    survivors = tune.prune(cands, keep)
    assert len(survivors) == min(keep, len(cands))
    assert best.cfg in [s.cfg for s in survivors]


def test_predictor_prefers_troop_mechanisms():
    """Sanity on the analytic model: decoupled streams beat the single
    interface on the paper's memory-bound kernels."""
    for name in ("gemv", "dotp", "axpy"):
        spec = tune.REGISTRY[name]
        args, _ = spec.example(small=True)
        lo = tune.predict_fraction(
            spec, TroopConfig(streams=1, unroll=1), *args)
        hi = tune.predict_fraction(
            spec, TroopConfig(streams=2, unroll=2), *args)
        assert hi > lo, name


# --------------------------------------------------------------------------
# cache + end-to-end tune -> dispatch
# --------------------------------------------------------------------------
def test_get_tuned_falls_back_on_miss(tmp_cache):
    spec = tune.REGISTRY["gemv"]
    w = jax.ShapeDtypeStruct((64, 256), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((256,), jnp.bfloat16)
    cfg = tune.get_tuned("gemv", w, x)
    assert cfg == spec.heuristic(w, x)
    assert tune.default_cache().misses >= 1


def test_tune_cache_roundtrip_and_no_retiming(tmp_cache):
    spec = tune.REGISTRY["rmsnorm"]
    args, kw = spec.example(small=True)
    res = tune.tune("rmsnorm", *args, kernel_kwargs=kw, keep=2, iters=1)
    assert not res.from_cache and res.timings_run >= 1
    assert res.measured_s is not None and res.fraction > 0

    # second call: resolved from cache, zero timing invocations
    res2 = tune.tune("rmsnorm", *args, kernel_kwargs=kw, keep=2, iters=1)
    assert res2.from_cache and res2.timings_run == 0
    assert res2.best == res.best

    # persisted: a brand-new cache instance reads the same best config
    assert os.path.exists(tmp_cache)
    fresh = tune.TuneCache(tmp_cache)
    assert len(fresh) == 1
    cfg = tune.get_tuned("rmsnorm", *args, cache=fresh)
    assert cfg == res.best

    # dispatch consumes it (shape-only lookup)
    structs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    assert tune.get_tuned("rmsnorm", *structs) == res.best


def test_cache_file_is_json_keyed_by_kernel_shape_backend(tmp_cache):
    spec = tune.REGISTRY["rmsnorm"]
    args, kw = spec.example(small=True)
    tune.tune("rmsnorm", *args, kernel_kwargs=kw, keep=1, iters=1)
    with open(tmp_cache) as f:
        data = json.load(f)
    (key,) = data.keys()
    assert key.startswith("rmsnorm|")
    assert key.endswith(f"|{jax.default_backend()}")
    assert "config" in data[key] and "fraction_of_roofline" in data[key]


def test_cache_tolerates_corrupt_file(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    c = tune.TuneCache(str(p))
    assert len(c) == 0
    c.put("k", {"config": tune.config_to_dict(TroopConfig())})
    c.save()
    assert len(tune.TuneCache(str(p))) == 1


def test_cache_lru_eviction_keeps_disk_contents(tmp_path):
    c = tune.TuneCache(str(tmp_path / "c.json"), capacity=2)
    for i in range(5):
        c.put(f"k{i}", {"config": tune.config_to_dict(TroopConfig())})
    assert len(c._lru) == 2            # hot view bounded
    assert len(c) == 5                 # disk dict complete
    assert c.get("k0") is not None     # evicted from LRU, still served


def test_tuned_serve_configs(tmp_cache):
    """serve.step consumes the tune cache at shape level."""
    from repro.configs.qwen15_05b import CONFIG as CFG
    from repro.serve.step import tuned_kernel_configs
    cfgs = tuned_kernel_configs(CFG, batch_size=2, max_seq=128)
    assert set(cfgs) == {"decode_attention", "decode_attention_int8",
                         "paged_decode_attention",
                         "paged_decode_attention_int8",
                         "prefill_attention_paged",
                         "gemv", "qgemv", "rmsnorm"}
    for v in cfgs.values():
        assert isinstance(v, TroopConfig)
