"""Trace-driven load harness -> table + BENCH_load.json.

Replays a seeded workload trace (``repro.obs.workload``) through the
serving engine in three modes — bucketed paged, chunked prefill, chunked +
prefix cache — under the deterministic step clock (``repro.obs.replay``)
and reports per-request latency percentiles in *engine cycles*
(``ttft_steps_p50/p95/p99``, ``tpot_steps_*``, ``wait_steps_p95``),
queue-depth / pool-occupancy timelines and defer/eviction counts.  The
step-clock percentiles are bit-identical run over run for a given
``(dist, seed)`` — ``benchmarks/ci_gate.py`` puts SLO bands on them, while
wall-clock (``*_s``) metrics stay info-only.

A second section joins the tune registry's byte models, the Spatz cycle
model and the Table-II energy constants (``repro.obs.energy``) into
modeled energy rows per engine config — bytes/token, joules/token,
tokens/s/W, fraction-of-roofline — for bf16 and int8 KV+weights.

Two profiler sections (``repro.obs.profiler``, DESIGN.md §9): ``profile``
re-runs the chunked+prefix replay under a ``DispatchProfiler`` and reports
per-phase dispatch counts + modeled bytes (deterministic, exact-gated) and
wall-derived roofline fractions (info); ``audit`` runs the decode-step
dispatch audit (measured kernel multiset == ``decode_step_account``) for
bf16 and int8 KV, gated as exact booleans.

    PYTHONPATH=src python benchmarks/load_bench.py --fast
    PYTHONPATH=src python benchmarks/load_bench.py --requests 64 \
        --trace-out BENCH_load_trace.json      # open in ui.perfetto.dev

Interpret-mode wall times on CPU are NOT TPU performance (DESIGN.md §3);
the step-clock latencies and modeled energy are hardware-independent.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

MODES = ("paged", "chunked", "chunked+prefix")


def build_engine(arch: str, mode: str, *, slots, cache_len, page_size,
                 chunk_size, tracer=None, profiler=None, tp=1,
                 speculate_k=0):
    """(arch, mode) -> (model cfg, engine) through ``repro.serve``'s one
    factory.  ``speculate_k`` > 0 adds a same-arch draft (seed-0 params on
    both sides -> 100% greedy acceptance, so the speculative metrics are
    deterministic and gateable)."""
    from repro.configs import get_config, reduced
    from repro.serve import EngineConfig
    from repro.serve import build_engine as _factory

    cfg = reduced(get_config(arch))
    base = mode.split("/")[0]
    engine_cfg = EngineConfig(
        slots=slots, cache_len=cache_len, backend="paged",
        page_size=page_size,
        chunked_prefill=base.startswith("chunked") or speculate_k > 0,
        chunk_size=chunk_size, prefix_cache=(base == "chunked+prefix"),
        speculate_k=speculate_k, tp=tp)
    draft = reduced(get_config(arch)) if speculate_k else None
    eng = _factory(cfg, engine_cfg, draft=draft, tracer=tracer,
                   profiler=profiler)
    return cfg, eng


def replay_mode(arch: str, mode: str, trace, *, slots, cache_len,
                page_size, chunk_size, prefix_len, tracer=None):
    from repro.obs import Replayer

    cfg, eng = build_engine(arch, mode, slots=slots, cache_len=cache_len,
                            page_size=page_size, chunk_size=chunk_size,
                            tracer=tracer)
    rep = Replayer(eng, prefix_len=prefix_len).run(
        trace, vocab_size=cfg.vocab_size)
    row = {"arch": cfg.name, "mode": mode, "dist": trace.meta.get("dist"),
           "seed": trace.meta.get("seed"), **rep.row()}
    return row, rep


def profile_rows(arch: str, trace, *, slots, cache_len, page_size,
                 chunk_size, prefix_len):
    """Profiled chunked+prefix replay: per-phase dispatch counts and
    modeled bytes (deterministic — exact CI gates) plus wall-derived
    roofline fractions (info)."""
    from repro.configs import get_config, reduced
    from repro.obs import (DispatchProfiler, Replayer, decode_step_account)

    cfg = reduced(get_config(arch))
    prof = DispatchProfiler()
    prof.seed_phase("decode", decode_step_account(
        cfg, slots=slots, cache_len=cache_len, page_size=page_size))
    _, eng = build_engine(arch, "chunked+prefix", slots=slots,
                          cache_len=cache_len, page_size=page_size,
                          chunk_size=chunk_size, profiler=prof)
    prof.install()
    try:
        Replayer(eng, prefix_len=prefix_len).run(
            trace, vocab_size=cfg.vocab_size)
    finally:
        prof.uninstall()
    return prof.phase_rows()


def audit_rows(arch: str, *, cache_len, page_size):
    """Dispatch audit (exact-match booleans + byte totals) for bf16 and
    int8 KV — the measured-vs-modeled invariant, gated exactly."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models import RuntimeConfig, build_model
    from repro.obs import audit_decode_step

    rows = []
    for kv_dtype in ("bfloat16", "int8"):
        cfg = reduced(get_config(arch))
        model = build_model(cfg, RuntimeConfig(
            remat="none", kv_cache_dtype="int8" if kv_dtype == "int8"
            else ""))
        a = audit_decode_step(model, cache_len=cache_len,
                              page_size=page_size)
        rows.append({"arch": cfg.name, "kv_dtype": kv_dtype,
                     "match": bool(a.ok),
                     "dispatches": a.dispatches,
                     "modeled_bytes_measured": int(a.measured_bytes),
                     "modeled_bytes_expected": int(a.expected_bytes)})
    return rows


def energy_rows(arch: str, *, slots, cache_len, page_size):
    from repro.configs import get_config, reduced
    from repro.obs import engine_energy_row

    cfg = reduced(get_config(arch))
    rows = []
    for kv_dtype, weights in (("bfloat16", "bfloat16"), ("int8", "int8")):
        rows.append(engine_energy_row(
            cfg, slots=slots, cache_len=cache_len, page_size=page_size,
            kv_dtype=kv_dtype, weights=weights))
    # the TROOP lever as a bytes/token ratio: same target stream amortized
    # over slots * (1 + k * acceptance) tokens per verify pass
    rows.append(engine_energy_row(
        cfg, slots=slots, cache_len=cache_len, page_size=page_size,
        kv_dtype="bfloat16", weights="bfloat16", speculate_k=3,
        acceptance=1.0))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--dist", default="heavy_tail")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="16-request smoke (CI); default is a 64-request "
                         "soak")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--prefix-len", type=int, default=24)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the chunked+prefix run's Chrome trace "
                         "(open in ui.perfetto.dev)")
    ap.add_argument("--workload-out", default=None, metavar="PATH",
                    help="also persist the workload trace as JSON-lines")
    ap.add_argument("--out", default="BENCH_load.json")
    args = ap.parse_args(argv)

    import jax
    from repro import obs

    requests = args.requests or (16 if args.fast else 64)
    trace = obs.generate(args.dist, requests=requests, seed=args.seed,
                         prompt_len=(4, min(48, args.cache_len - 18)),
                         max_new=(2, 16))
    if args.workload_out:
        trace.to_jsonl(args.workload_out)
        print(f"wrote {args.workload_out}")

    rows = []
    for mode in MODES:
        tracer = obs.Tracer() if mode == "chunked+prefix" else None
        row, _ = replay_mode(
            args.arch, mode, trace, slots=args.slots,
            cache_len=args.cache_len, page_size=args.page_size,
            chunk_size=args.chunk_size, prefix_len=args.prefix_len,
            tracer=tracer)
        rows.append(row)
        print(f"{mode:<15} ttft_steps p50/p95/p99 "
              f"{row['ttft_steps_p50']:.1f}/{row['ttft_steps_p95']:.1f}/"
              f"{row['ttft_steps_p99']:.1f}  "
              f"tpot_steps p95 {row['tpot_steps_p95']:.2f}  "
              f"queue max {row['queue_depth_max']}  "
              f"defers {row['deferrals']}  "
              f"drained={row['all_finished']}")
        if tracer is not None and args.trace_out:
            tracer.to_chrome(args.trace_out)
            print(f"wrote {args.trace_out} ({len(tracer.events())} events, "
                  f"{tracer.dropped} dropped)")

    energy = energy_rows(args.arch, slots=args.slots,
                         cache_len=args.cache_len,
                         page_size=args.page_size)
    for e in energy:
        if e.get("speculate_k"):
            print(f"energy bf16/spec-k{e['speculate_k']} "
                  f"{e['bytes_per_token']:>8} B/tok  "
                  f"{e['joules_per_token']*1e6:>8.3f} uJ/tok  "
                  f"{e['tokens_per_s_per_w']:>10.0f} tok/s/W  "
                  f"roofline frac {e['fraction_of_roofline']:.3f}")
            continue
        print(f"energy {e['kv_dtype']:<9} {e['bytes_per_token']:>8} B/tok  "
              f"{e['joules_per_token']*1e6:>8.3f} uJ/tok  "
              f"{e['tokens_per_s_per_w']:>10.0f} tok/s/W  "
              f"roofline frac {e['fraction_of_roofline']:.3f}")

    profile = profile_rows(args.arch, trace, slots=args.slots,
                           cache_len=args.cache_len,
                           page_size=args.page_size,
                           chunk_size=args.chunk_size,
                           prefix_len=args.prefix_len)
    for p in profile:
        print(f"profile {p['phase']:<16} {p['occurrences']:>4} occ  "
              f"{p['dispatches']:>6} dispatches  "
              f"{p['modeled_bytes']:>12,} B modeled")
    audit = audit_rows(args.arch, cache_len=args.cache_len,
                       page_size=args.page_size)
    for a in audit:
        print(f"audit  kv={a['kv_dtype']:<9} match={a['match']}  "
              f"{a['dispatches']} dispatches  "
              f"{a['modeled_bytes_measured']:,} B")

    payload = {
        "backend": jax.default_backend(),
        "interpret_mode": True,
        "workload": trace.meta,
        "rows": rows,
        "energy": energy,
        "profile": profile,
        "audit": audit,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
