"""Engine construction API: ``EngineConfig`` + ``build_engine``.

``ServingEngine.__init__`` had grown ~20 loose kwargs and three
construction sites (the launcher, serve_bench, load_bench) each
hand-rolled an overlapping subset.  ``EngineConfig`` is the one frozen
record of every scalar engine option — scheduler shape, cache geometry,
sampling, quantization, tensor parallelism, and the speculative-decoding
options (which land ONLY here, never as new constructor kwargs) — and
``build_engine`` is the one factory that turns (arch, EngineConfig) into
a running engine: model + params + backend + compiled steps + draft pair.

Legacy keyword construction (``ServingEngine(model, slots=..., ...)``)
keeps working for one release through a shim that emits a
``DeprecationWarning`` and forwards into an ``EngineConfig``
(DESIGN.md §10 has the migration table).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

#: constructor kwargs accepted by the legacy ``ServingEngine`` shim —
#: exactly the EngineConfig fields that used to be loose kwargs.
LEGACY_ENGINE_KWARGS = frozenset({
    "slots", "cache_len", "stop_token", "prefill_batch", "min_bucket",
    "chunked_prefill", "chunk_size", "chunks_per_step", "prefix_cache",
    "metrics_window", "tp", "tp_mode", "async_dispatch",
})


@dataclass(frozen=True)
class EngineConfig:
    """Every scalar option of a serving engine, in one frozen record.

    ``build_engine`` consumes the full config; ``ServingEngine`` consumes
    the scheduler subset (and ignores the factory-level fields such as
    ``quantize_weights``, which shape the params before the engine ever
    sees them).
    """
    # scheduler shape
    slots: int = 4
    cache_len: int = 128
    stop_token: int = -1
    metrics_window: int = 4096
    # cache backend geometry
    backend: str = "dense"               # "dense" | "paged"
    page_size: Optional[int] = None      # None -> layout granule default
    num_pages: Optional[int] = None      # None -> full occupancy
    kv_cache_dtype: str = ""             # "" -> model dtype | "int8"
    # prefill strategy
    prefill_batch: Optional[int] = None
    min_bucket: int = 8
    chunked_prefill: bool = False
    chunk_size: int = 32
    chunks_per_step: int = 1
    prefix_cache: bool = False
    # sampling
    temperature: float = 0.0
    seed: int = 0
    # speculative decoding (the only home for these options)
    draft_arch: Optional[str] = None
    speculate_k: int = 0
    # tensor parallelism / dispatch
    tp: int = 1
    tp_mode: str = "exact"
    async_dispatch: bool = True
    # factory-level (resolved before ServingEngine construction)
    kernel_decode: bool = False
    quantize_weights: str = "none"  # "none" | "int8" | "int4" | "mx4" | "fp8"
    quantize_group_size: int = 128

    def validate(self) -> "EngineConfig":
        """Cross-field coherence; raises ``ValueError`` with the same
        messages the launcher surfaces at argparse time."""
        if self.backend not in ("dense", "paged"):
            raise ValueError(f"backend must be 'dense' or 'paged', "
                             f"got {self.backend!r}")
        if self.chunked_prefill and self.backend != "paged":
            raise ValueError("chunked_prefill requires backend='paged' "
                             "(slabs write through block tables)")
        if self.prefix_cache and not self.chunked_prefill:
            raise ValueError("prefix_cache requires chunked_prefill (a "
                             "prefix hit resumes prefill mid-prompt)")
        if self.kernel_decode and self.backend != "paged":
            raise ValueError("kernel_decode requires backend='paged' (the "
                             "kernel reads the page pool + block table)")
        if self.speculate_k < 0:
            raise ValueError("speculate_k must be >= 0")
        if self.speculate_k:
            if not self.chunked_prefill:
                raise ValueError(
                    "speculative decoding requires chunked_prefill (the "
                    "verify pass reuses the chunked slab attention path)")
            if self.tp != 1:
                raise ValueError("speculative decoding is single-device "
                                 "for now (tp must be 1)")
        if self.draft_arch is not None and not self.speculate_k:
            raise ValueError("draft_arch is set but speculate_k == 0 — "
                             "pass speculate_k > 0 to enable speculation")
        if self.tp < 1:
            raise ValueError("tp must be >= 1")
        if self.quantize_weights not in ("none", "int8", "int4",
                                         "mx4", "fp8"):
            raise ValueError(f"quantize_weights must be one of none|int8|"
                             f"int4|mx4|fp8, got {self.quantize_weights!r}")
        if self.quantize_weights in ("int4", "mx4") and self.tp > 1:
            raise ValueError(
                f"{self.quantize_weights} packs row pairs along the "
                f"contraction axis that would straddle the tensor-parallel "
                f"shard boundary; use int8 or fp8 under tp > 1")
        return self

    @classmethod
    def from_legacy_kwargs(cls, **kw: Any) -> "EngineConfig":
        """Build a config from the legacy ``ServingEngine`` kwargs."""
        unknown = set(kw) - LEGACY_ENGINE_KWARGS
        if unknown:
            raise TypeError(
                f"ServingEngine got unexpected keyword argument(s) "
                f"{sorted(unknown)} — new options live on EngineConfig "
                f"(pass config=EngineConfig(...))")
        return cls(**kw)


def resolve_page_size(engine_cfg: EngineConfig) -> int:
    """The page size the factory allocates with: the explicit value, else
    the layout granule (32 rows for int8 pools, 16 for bf16)."""
    if engine_cfg.page_size is not None:
        return engine_cfg.page_size
    if engine_cfg.kv_cache_dtype == "int8":
        from repro.quant.tensor import granule
        return granule()
    return 16


def build_engine(arch, engine_cfg: Optional[EngineConfig] = None, *,
                 params=None, draft=None, draft_params=None, tracer=None,
                 profiler=None, prefill_extras=None):
    """The one engine factory: ``(arch, EngineConfig) -> ServingEngine``.

    ``arch`` is a registry id (``"qwen1.5-0.5b"``), a model config object
    (e.g. ``reduced(get_config(...))``), or a prebuilt ``Model`` facade
    (its RuntimeConfig then wins over the config's runtime fields).
    ``params`` defaults to a seed-0 init (quantized per the config);
    ``draft`` optionally overrides ``engine_cfg.draft_arch`` with a config
    object or prebuilt model (reduced smoke runs pass a reduced draft cfg).
    """
    import jax

    from repro.configs import get_config
    from repro.models import RuntimeConfig, build_model
    from repro.models import modules as M
    from repro.serve.kvcache import PagedBackend
    from repro.serve.scheduler import ServingEngine
    from repro.serve.step import (make_prefill_step, make_serve_step,
                                  tuned_kernel_configs)

    cfg_e = (engine_cfg if engine_cfg is not None
             else EngineConfig()).validate()

    if hasattr(arch, "decode_step"):          # prebuilt Model facade
        model = arch
    else:
        cfg = get_config(arch) if isinstance(arch, str) else arch
        model = build_model(cfg, RuntimeConfig(
            remat="none", paged_kernel_decode=cfg_e.kernel_decode,
            quantize_weights=cfg_e.quantize_weights,
            kv_cache_dtype=cfg_e.kv_cache_dtype))
    cfg = model.cfg

    if params is None:
        params = M.unbox(model.init(jax.random.PRNGKey(0)))
        if cfg_e.quantize_weights in ("mx4", "fp8"):
            from repro.quant import quantize_params
            params = quantize_params(params, fmt=cfg_e.quantize_weights,
                                     tp=cfg_e.tp)
        elif cfg_e.quantize_weights != "none":
            from repro.quant import quantize_params
            params = quantize_params(
                params, bits=8 if cfg_e.quantize_weights == "int8" else 4,
                group_size=cfg_e.quantize_group_size, tp=cfg_e.tp)

    page_size = resolve_page_size(cfg_e)
    if cfg_e.backend == "paged":
        backend = PagedBackend(
            page_size=page_size, num_pages=cfg_e.num_pages,
            kv_dtype="int8" if cfg_e.kv_cache_dtype == "int8" else None,
            prefix_cache=cfg_e.prefix_cache)
        configs = tuned_kernel_configs(
            cfg, cfg_e.slots, cfg_e.cache_len, page_size=page_size,
            num_pages=cfg_e.num_pages, chunk_size=cfg_e.chunk_size)
    else:
        backend, configs = "dense", None

    draft_model = None
    if cfg_e.speculate_k:
        if draft is None:
            if cfg_e.draft_arch is None:
                raise ValueError("speculate_k > 0 needs a draft model: set "
                                 "EngineConfig.draft_arch or pass draft=")
            draft = get_config(cfg_e.draft_arch)
        if hasattr(draft, "decode_step"):
            draft_model = draft
        else:
            draft_model = build_model(draft, RuntimeConfig(remat="none"))
        if draft_params is None:
            draft_params = M.unbox(draft_model.init(jax.random.PRNGKey(0)))

    return ServingEngine(
        model, config=cfg_e, params=params,
        prefill_step=make_prefill_step(model),
        serve_step=make_serve_step(model, temperature=cfg_e.temperature,
                                   seed=cfg_e.seed, troop_configs=configs),
        backend=backend, prefill_extras=prefill_extras, tracer=tracer,
        profiler=profiler, draft_model=draft_model,
        draft_params=draft_params)
