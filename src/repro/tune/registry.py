"""Kernel registry — the tuning subsystem's source of truth.

Every Pallas kernel in ``repro.kernels`` registers itself with
``@troop_kernel(name, flops=..., bytes=...)``, declaring:

  * a roofline cost model (``flops`` / ``bytes`` callables over the call's
    positional arguments — only ``.shape``/``.dtype`` are read, so
    ``jax.ShapeDtypeStruct`` placeholders work),
  * its tunable ``TroopConfig`` space (knob -> candidate values),
  * the name of its pure-jnp oracle in ``repro.kernels.ref`` (resolved
    lazily to avoid import cycles),
  * an example-args factory used by ``benchmarks/tune_report.py`` and the
    test suite.

The decorator returns a *dispatching* wrapper: called with an explicit
``TroopConfig`` (positionally or as ``cfg=``) it behaves exactly like the
raw kernel; called without one it resolves the best-known config through
``repro.tune.cache.get_tuned`` (persistent tuned cache, falling back to the
spec's heuristic default).  The raw kernel stays reachable as
``spec.fn`` so the search engine never recurses through dispatch.

When a ``repro.obs.profiler.DispatchProfiler`` is installed (module global
``PROFILER``, via ``install_profiler``), every dispatch routes through
``profiler.record`` which logs the call (name, arg signature, resolved
config, modeled flops/bytes) before invoking the kernel with the exact
config the plain path would have used.  With no profiler installed the
wrapper pays a single module-attr check — nothing else.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

from repro.core.troop import TroopConfig

# Knob -> candidate values swept when a kernel does not restrict its space.
# (streams x unroll x block_n x block_k x layout — the paper's §IV axes.)
DEFAULT_SPACE: Mapping[str, Tuple] = {
    "streams": (1, 2),
    "unroll": (1, 2),
    "block_n": (128, 256),
    "block_k": (256, 512),
    "scrambled_layout": (False, True),
}


def itemsize(a) -> int:
    """Bytes per element; works on arrays, tracers and ShapeDtypeStructs."""
    import jax.numpy as jnp
    return jnp.dtype(a.dtype).itemsize


def numel(a) -> int:
    """Element count; works on arrays, tracers and ShapeDtypeStructs."""
    import math
    return int(math.prod(a.shape))


def arg_signature(args: Sequence[Any]) -> str:
    """``f32[128,512],bf16[512]`` — shape/dtype key of the array args.
    Non-array positional args (variant flags, scalar coefficients) key by
    ``repr`` so different kernel variants never share a cache entry."""
    import jax.numpy as jnp
    parts = []
    for a in args:
        shape = getattr(a, "shape", None)
        dtype = getattr(a, "dtype", None)
        if shape is None or dtype is None:
            if a is not None and not isinstance(a, TroopConfig):
                parts.append(repr(a)[:32])
            continue
        name = jnp.dtype(dtype).name.replace("float", "f").replace(
            "int", "i").replace("buint", "bui")
        parts.append(f"{name}[{','.join(str(int(d)) for d in shape)}]")
    return ",".join(parts)


def cache_key(name: str, args: Sequence[Any], backend: Optional[str] = None,
              variant: Optional[Mapping[str, Any]] = None) -> str:
    if backend is None:
        import jax
        backend = jax.default_backend()
    var = "".join(f"|{k}={repr(variant[k])[:32]}"
                  for k in sorted(variant)) if variant else ""
    return f"{name}|{arg_signature(args)}|{backend}{var}"


def operand_bytes(operands) -> float:
    """Total bytes of a streamed-operand list (arrays / ShapeDtypeStructs).

    The audit invariant behind every ``bytes=`` cost model: modeled traffic
    must equal the sum of the operands the kernel actually streams —
    including scale tensors for quantized layouts (tested registry-wide)."""
    return float(sum(numel(o) * itemsize(o) for o in operands))


@dataclass(frozen=True)
class KernelSpec:
    name: str
    fn: Callable                      # raw kernel: fn(*args, cfg=..., **kw)
    flops: Callable                   # (*args) -> float (useful FLOPs)
    bytes: Callable                   # (*args) -> float (min HBM traffic)
    space: Mapping[str, Tuple] = field(default_factory=lambda: DEFAULT_SPACE)
    ref: Optional[str] = None         # oracle name in repro.kernels.ref
    example: Optional[Callable] = None  # (small=True) -> (args, kwargs)
    default: TroopConfig = TroopConfig()
    key_kwargs: Tuple[str, ...] = ()  # kwargs that select a kernel variant
    streamed: Optional[Callable] = None  # (*args) -> streamed-operand list
    #   (each with .shape/.dtype; sum of nbytes must equal bytes(*args) —
    #   scalar/SMEM prefetch args are excluded by convention)

    def reference(self) -> Optional[Callable]:
        if self.ref is None:
            return None
        mod = importlib.import_module("repro.kernels.ref")
        return getattr(mod, self.ref)

    def heuristic(self, *args) -> TroopConfig:
        """Untuned fallback: the spec default (the repo's TROOP preset
        semantics — streams=2, hardware-granule blocks, interpret on CPU)."""
        return self.default

    def key(self, *args, backend: Optional[str] = None,
            kwargs: Optional[Mapping[str, Any]] = None) -> str:
        variant = {k: kwargs[k] for k in self.key_kwargs
                   if kwargs and k in kwargs}
        return cache_key(self.name, args, backend, variant)


REGISTRY: Dict[str, KernelSpec] = {}

# Installed DispatchProfiler (repro.obs.profiler) or None.  The dispatch
# wrapper below reads this module global once per call — the disabled path
# costs exactly one attr check and nothing else.
PROFILER: Optional[Any] = None


def install_profiler(profiler) -> None:
    """Route every registry dispatch through ``profiler.record``."""
    global PROFILER
    PROFILER = profiler


def uninstall_profiler(profiler=None) -> None:
    """Remove the installed profiler (no-op if ``profiler`` isn't it)."""
    global PROFILER
    if profiler is None or PROFILER is profiler:
        PROFILER = None


def get(name: str) -> KernelSpec:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(REGISTRY)}"
            " (import repro.kernels to populate the registry)")
    return REGISTRY[name]


def names() -> Tuple[str, ...]:
    return tuple(sorted(REGISTRY))


def troop_kernel(name: str, *, flops: Callable, bytes: Callable,
                 space: Optional[Mapping[str, Tuple]] = None,
                 ref: Optional[str] = None,
                 example: Optional[Callable] = None,
                 default: Optional[TroopConfig] = None,
                 key_kwargs: Tuple[str, ...] = (),
                 streamed: Optional[Callable] = None):
    """Register a kernel and return its registry-dispatching wrapper."""
    def deco(fn: Callable) -> Callable:
        spec = KernelSpec(
            name=name, fn=fn, flops=flops, bytes=bytes,
            space=dict(space) if space is not None else dict(DEFAULT_SPACE),
            ref=ref, example=example,
            default=default if default is not None else TroopConfig(),
            key_kwargs=tuple(key_kwargs), streamed=streamed)
        REGISTRY[name] = spec

        def dispatch(*args, **kwargs):
            prof = PROFILER            # one module-attr load when disabled
            if prof is not None:
                return prof.record(spec, fn, args, kwargs)
            if kwargs.get("cfg") is not None or \
                    any(isinstance(a, TroopConfig) for a in args):
                return fn(*args, **kwargs)
            kwargs.pop("cfg", None)       # cfg=None -> dispatch
            from repro.tune.cache import get_tuned
            return fn(*args, cfg=get_tuned(name, *args, variant_kwargs=kwargs),
                      **kwargs)

        # manual wraps: jitted callables are C objects without a plain
        # __dict__ for functools.wraps to copy
        dispatch.__name__ = getattr(fn, "__name__", name)
        dispatch.__doc__ = getattr(fn, "__doc__", None)
        dispatch.__wrapped__ = fn
        dispatch.spec = spec
        return dispatch
    return deco
