"""Quick dev loop: run every reduced arch through train/prefill/decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build_model, RuntimeConfig
from repro.models import modules as M

B, T = 2, 16


def run(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, RuntimeConfig(remat="none", moe_groups=1))
    key = jax.random.PRNGKey(0)
    boxed = model.init(key)
    params = M.unbox(boxed)
    n_params = sum(x.size for x in jax.tree.leaves(params))

    tok_len = T - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    batch = {"tokens": jnp.ones((B, tok_len), jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.ones((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.encoder_decoder:
        batch["frontend"] = jnp.ones((B, cfg.cross_attention_len, cfg.d_model),
                                     jnp.bfloat16)

    logits, aux = model.train_logits(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size), (arch, logits.shape)
    assert not jnp.isnan(logits.astype(jnp.float32)).any(), arch

    # prefill + one decode step
    _, caches_p = model.prefill(params, batch)
    caches = model.init_caches(B, 32)
    step = {"tokens": jnp.ones((B, 1), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32)}
    lg, caches = model.decode_step(params, step, caches)
    assert lg.shape == (B, 1, cfg.vocab_size), (arch, lg.shape)
    assert not jnp.isnan(lg.astype(jnp.float32)).any(), arch
    print(f"OK {arch:24s} params={n_params:,} logits={logits.shape}")


if __name__ == "__main__":
    archs = sys.argv[1:] or ARCH_IDS
    for a in archs:
        run(a)
