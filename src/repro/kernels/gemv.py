"""GEMV kernel — the paper's flagship memory-bound workload.

y = W @ x with W (N,K) streamed from HBM exactly once (operational intensity
~= 1 FLOP/byte at bf16: deep under the v5e ridge of 240, so runtime ==
bytes/BW iff every optimization below holds — the paper's "at-the-roofline"
condition).

TROOP mechanisms:
  (A) streams=2: W and x fetched as two disjoint contiguous half-streams of
      the K dimension (independent BlockSpecs -> two in-flight DMAs/step).
  (B) grid pipeline overlaps block DMA with the MXU tile matmul.
  (C) fp32 accumulator lives in VMEM scratch; y commits once per row-tile
      (no per-step output DMA: the shadow-buffer intent).
  (F) unroll=2: two K-tiles per stream per grid step.
  (G) the K-reduction is tree-shaped inside the tile (jnp.dot) + sequential
      scratch accumulation across tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel


def _example(small: bool = True):
    key = jax.random.PRNGKey(0)
    N, K = (128, 512) if small else (2048, 4096)
    w = jax.random.normal(key, (N, K), jnp.bfloat16)
    x = jax.random.normal(key, (K,), jnp.bfloat16)
    return (w, x), {}


def _kernel_1s(w_ref, x_ref, o_ref, acc):
    """Baseline: single interface."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(w_ref[...].astype(jnp.float32),
                        x_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _kernel_2s(w0_ref, w1_ref, x0_ref, x1_ref, o_ref, acc):
    """TROOP: two decoupled interfaces (contiguous K halves)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    a = jnp.dot(w0_ref[...].astype(jnp.float32),
                x0_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    b = jnp.dot(w1_ref[...].astype(jnp.float32),
                x1_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    acc[...] += a + b          # two accumulation chains folded per step

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@troop_kernel(
    "gemv",
    flops=lambda w, x: 2.0 * w.shape[0] * w.shape[1],
    bytes=lambda w, x: (w.shape[0] * w.shape[1] * itemsize(w)
                        + w.shape[1] * itemsize(x) + w.shape[0] * 4),
    streamed=lambda w, x: [
        w, x, jax.ShapeDtypeStruct((w.shape[0],), jnp.float32)],
    space={"streams": (1, 2), "unroll": (1, 2),
           "block_n": (128, 256), "block_k": (256, 512)},
    ref="gemv", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def gemv(w, x, cfg: TroopConfig = TroopConfig()):
    """w (N,K), x (K,) -> y (N,) fp32."""
    N, K = w.shape
    bn = min(cfg.block_n, N)
    bk = min(cfg.block_k * cfg.unroll, K)
    x2 = x.reshape(K, 1)

    if cfg.streams == 1:
        while K % bk:
            bk //= 2
        grid = (N // bn, K // bk)
        return pl.pallas_call(
            _kernel_1s,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
                pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
            scratch_shapes=[_scratch(bn)],
            interpret=cfg.interpret,
        )(w, x2).reshape(N)

    # streams == 2: stream0 = first K half, stream1 = second K half
    Kh = K // 2
    while Kh % bk:
        bk //= 2
    steps = Kh // bk
    grid = (N // bn, steps)
    off = steps  # block offset of the second half

    return pl.pallas_call(
        _kernel_2s,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bk), lambda i, j, o=off: (i, j + o)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, 1), lambda i, j, o=off: (j + o, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        scratch_shapes=[_scratch(bn)],
        interpret=cfg.interpret,
    )(w, w, x2, x2).reshape(N)


def _scratch(bn):
    from jax.experimental import pallas as pl
    import jax.experimental.pallas.tpu as pltpu
    return pltpu.VMEM((bn, 1), jnp.float32)
