"""End-to-end serving driver (the paper's workload): batched requests
through continuous batching, decode dominated by GEMV-class kernels.

    PYTHONPATH=src python examples/serve_decode.py --requests 12 --slots 4

Serves a reduced model with batched prefill+decode; reports decode
steps/sec and tokens generated (the end-to-end driver per deliverable (b)).
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import RuntimeConfig, build_model
from repro.models import modules as M
from repro.serve import EngineConfig, Request, build_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--backend", choices=("dense", "paged"), default="paged")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg, RuntimeConfig(remat="none"))
    params = M.unbox(model.init(jax.random.PRNGKey(0)))
    print(f"serving {cfg.name}: params={cfg.param_count():,} "
          f"slots={args.slots} backend={args.backend}")

    engine = build_engine(
        model, EngineConfig(slots=args.slots, cache_len=128,
                            backend=args.backend),
        params=params)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        engine.submit(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, plen),
            max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    finished = engine.run_until_drained()
    dt = time.perf_counter() - t0
    m = engine.metrics()
    print(f"generated {m['tokens_generated']} tokens "
          f"({len(finished)} requests) in {engine.steps} decode steps, "
          f"{dt:.1f}s ({m['tokens_generated'] / dt:.1f} tok/s on CPU, "
          f"{m['prefill_traces']} prefill compiles)")


if __name__ == "__main__":
    main()
