"""Loss functions: softmax cross-entropy (optionally chunked + rematerialized).

The chunked variant recomputes per-chunk logits in the backward pass so the
full (B, T, vocab) logits tensor is never resident — the decisive activation-
memory term for large-vocab archs (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, targets, z_loss: float = 1e-4):
    """logits (B,T,V) any dtype; targets (B,T) int32. fp32 math, mean loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)


def chunked_softmax_xent(x, readout_fn, targets, chunk: int,
                         z_loss: float = 1e-4):
    """x (B,T,d) final hidden; logits computed chunk-by-chunk under remat."""
    B, T, _ = x.shape
    if chunk <= 0 or T % chunk:
        return softmax_xent(readout_fn(x), targets, z_loss)
    n = T // chunk

    @jax.checkpoint
    def one(xc, tc):
        return softmax_xent(readout_fn(xc), tc, z_loss) * (chunk / T)

    def body(acc, xs):
        xc, tc = xs
        return acc + one(xc, tc), None

    xs = (x.reshape(B, n, chunk, -1).swapaxes(0, 1),
          targets.reshape(B, n, chunk).swapaxes(0, 1))
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total


def token_accuracy(logits, targets):
    return jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
