"""GPipe pipeline parallelism (skewed schedule over stacked stage params).

``make_pipeline_fn(stage_fn, mesh, num_microbatches)`` returns
``pipe(stage_params, x)`` == applying the S stages sequentially, executed
as the classic pipeline: all stages run every tick (vmap over the stacked
stage axis == one device per stage under the ``stage`` mesh axis), with
microbatch m entering stage s at tick m + s.  ``bubble_fraction`` is the
idle share (S-1)/(M+S-1) — the quantity the paper's chaining analysis
minimizes, here at mesh scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def bubble_fraction(stages: int, microbatches: int) -> float:
    return (stages - 1) / (microbatches + stages - 1)


def make_pipeline_fn(stage_fn, mesh=None, num_microbatches: int = 8):
    """stage_fn(params_s, x_mb) -> x_mb; stage params stacked on axis 0."""
    M = num_microbatches

    def pipe(stage_params, x):
        S = jax.tree.leaves(stage_params)[0].shape[0]
        B = x.shape[0]
        assert B % M == 0, (B, M)
        mbs = x.reshape(M, B // M, *x.shape[1:])
        buf = jnp.zeros((S,) + mbs.shape[1:], x.dtype)
        outs = jnp.zeros_like(mbs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 consumes microbatch t (garbage after the last one —
            # its output never reaches the collect point below)
            feed = mbs[jnp.clip(t, 0, M - 1)]
            inputs = jnp.concatenate([feed[None], buf[:-1]], axis=0)
            new_buf = jax.vmap(stage_fn)(stage_params, inputs)
            if mesh is not None and "stage" in mesh.axis_names:
                spec = P("stage", *([None] * (new_buf.ndim - 1)))
                new_buf = jax.lax.with_sharding_constraint(
                    new_buf, NamedSharding(mesh, spec))
            # the last stage's output at tick t is microbatch t - (S-1)
            m = t - (S - 1)
            valid = (m >= 0) & (m < M)
            idx = jnp.clip(m, 0, M - 1)
            outs = jnp.where(valid, outs.at[idx].set(new_buf[-1]), outs)
            return (new_buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(M + S - 1))
        return outs.reshape(B, *x.shape[1:])

    return pipe
