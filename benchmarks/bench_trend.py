"""Nightly SLO trend: append one row per run to ``BENCH_trend.jsonl``.

The nightly bench uploads per-run BENCH artifacts, but a slow drift in
serving latency or modeled efficiency is invisible in any single run.
This script distills a fresh ``BENCH_load.json`` / ``BENCH_serve.json``
into one JSON-lines row — date, commit, TTFT / TPOT p95 (step clock,
deterministic; wall p95 as info) and modeled tokens/s/W for bf16 and int8
— appends it to a carried-forward ``BENCH_trend.jsonl`` (the nightly
workflow restores the previous run's artifact first, so the file grows
across runs), and renders a last-7-runs delta table to stdout and to
``$GITHUB_STEP_SUMMARY`` when set.

    python benchmarks/bench_trend.py                 # after the benches
    python benchmarks/bench_trend.py --trend my.jsonl --no-append
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

METRICS = ("ttft_steps_p95", "tpot_steps_p95", "ttft_s_p95",
           "tokens_per_s", "tok_s_w_bf16", "tok_s_w_int8",
           "soak_ttft_steps_p95", "soak_tpot_steps_p95")


def build_row(load_path, serve_path):
    """One trend row from the fresh BENCH files (missing files/fields
    leave nulls — the trend line must survive a partial nightly)."""
    row = {"date": datetime.datetime.now(datetime.timezone.utc)
           .strftime("%Y-%m-%dT%H:%M:%SZ"),
           "commit": os.environ.get("GITHUB_SHA", "")[:12]}
    for m in METRICS:
        row[m] = None
    if os.path.exists(load_path):
        load = json.load(open(load_path))
        cp = next((r for r in load.get("rows", [])
                   if r.get("mode") == "chunked+prefix"), None)
        if cp:
            for m in ("ttft_steps_p95", "tpot_steps_p95", "ttft_s_p95",
                      "tokens_per_s"):
                if m in cp:
                    row[m] = cp[m]
        for e in load.get("energy", []):
            key = {"bfloat16": "tok_s_w_bf16",
                   "int8": "tok_s_w_int8"}.get(e.get("kv_dtype"))
            if key and "tokens_per_s_per_w" in e:
                row[key] = e["tokens_per_s_per_w"]
    if os.path.exists(serve_path):
        serve = json.load(open(serve_path))
        soak = serve.get("soak")
        if soak:
            row["soak_ttft_steps_p95"] = soak.get("ttft_steps_p95")
            row["soak_tpot_steps_p95"] = soak.get("tpot_steps_p95")
    return row


def _fmt(v):
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _delta(prev, cur):
    if not isinstance(prev, (int, float)) or not isinstance(
            cur, (int, float)) or not prev:
        return ""
    return f" ({(cur - prev) / abs(prev) * 100:+.1f}%)"


def markdown(rows, window=7):
    tail = rows[-window:]
    keys = ["date", "commit"] + [m for m in METRICS
                                 if any(r.get(m) is not None for r in tail)]
    out = [f"## SLO trend (last {len(tail)} runs)", "",
           "| " + " | ".join(keys) + " |",
           "|" + "---|" * len(keys)]
    prev = None
    for r in tail:
        cells = []
        for k in keys:
            cell = _fmt(r.get(k))
            if prev is not None and k not in ("date", "commit"):
                cell += _delta(prev.get(k), r.get(k))
            cells.append(cell)
        out.append("| " + " | ".join(cells) + " |")
        prev = r
    out.append("")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--load", default="BENCH_load.json")
    ap.add_argument("--serve", default="BENCH_serve.json")
    ap.add_argument("--trend", default="BENCH_trend.jsonl")
    ap.add_argument("--window", type=int, default=7)
    ap.add_argument("--no-append", action="store_true",
                    help="render the existing trend file without adding "
                         "a new row")
    args = ap.parse_args(argv)

    rows = []
    if os.path.exists(args.trend):
        with open(args.trend) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rows.append(json.loads(line))
                except json.JSONDecodeError:
                    print(f"skip malformed trend line: {line[:60]}",
                          file=sys.stderr)
    if not args.no_append:
        row = build_row(args.load, args.serve)
        rows.append(row)
        with open(args.trend, "a") as f:
            f.write(json.dumps(row) + "\n")
        print(f"appended run {row['date']} ({row['commit'] or 'no sha'}) "
              f"-> {args.trend} ({len(rows)} rows)")
    if not rows:
        print("no trend rows yet")
        return 0
    md = markdown(rows, window=args.window)
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
