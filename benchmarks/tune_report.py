"""Per-kernel roofline-tuning report -> table + BENCH_tune.json.

Runs the full tune subsystem end to end for each registered kernel:
enumerate the TroopConfig space, prune analytically, time the survivors
(interpret mode on CPU — wall times are NOT TPU performance, but the
tune -> cache -> dispatch loop is exercised for real), and report each
kernel's best config with its fraction-of-roofline score.  A second
invocation resolves every kernel from the persistent cache without
re-timing (the acceptance check in tests/test_tune.py).

    PYTHONPATH=src python benchmarks/tune_report.py --fast

``--fast`` uses the registry's small example shapes, 2 survivors and 1
timing iteration per candidate (CI smoke).  Set REPRO_TUNE_BW to a
measured host bandwidth to make interpret-mode fractions meaningful;
the default denominator is the TPU v5e HBM roofline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# runnable as `python benchmarks/tune_report.py` without PYTHONPATH=src
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


FAST_KERNELS = ("gemv", "dotp", "axpy", "rmsnorm")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="small shapes, keep=2, iters=1 (CI smoke)")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated subset (default: fast four / all)")
    ap.add_argument("--keep", type=int, default=None,
                    help="survivors of the analytic prune per kernel")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per survivor")
    ap.add_argument("--force", action="store_true",
                    help="retune even when cached")
    ap.add_argument("--out", default="BENCH_tune.json")
    args = ap.parse_args(argv)

    import repro.kernels  # noqa: F401  (populates the registry)
    from repro import tune
    from repro.core.roofline import PEAK_FLOPS
    from repro.tune.search import roofline_bw
    import jax

    keep = args.keep if args.keep is not None else (2 if args.fast else 4)
    iters = args.iters if args.iters is not None else (1 if args.fast else 3)
    if args.kernels:
        names = tuple(k.strip() for k in args.kernels.split(",") if k.strip())
    else:
        names = FAST_KERNELS if args.fast else tune.names()

    cache = tune.default_cache()
    rows = []
    for name in names:
        if name not in tune.REGISTRY:
            print(f"-- unknown kernel {name!r}; registered: "
                  f"{', '.join(tune.names())}", file=sys.stderr)
            continue
        spec = tune.REGISTRY[name]
        if spec.example is None:
            print(f"-- {name}: no example factory, skipped", file=sys.stderr)
            continue
        kargs, kkw = spec.example(small=args.fast)
        t0 = time.time()
        res = tune.tune(name, *kargs, kernel_kwargs=kkw, keep=keep,
                        iters=iters, cache=cache, force=args.force)
        b = res.best
        rows.append({
            "kernel": name,
            "key": res.key,
            "config": tune.config_to_dict(b),
            "fraction_of_roofline": res.fraction,
            "predicted_fraction": res.predicted,
            "measured_us": (res.measured_s or 0.0) * 1e6,
            "roofline_us": res.roofline_s * 1e6,
            "from_cache": res.from_cache,
            "timings_run": res.timings_run,
            "tune_wall_s": time.time() - t0,
        })

    hdr = (f"{'kernel':<18}{'best config':<26}{'frac-roofline':>14}"
           f"{'predicted':>10}{'meas_us':>10}{'roof_us':>10}{'cached':>8}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        c = r["config"]
        cfg_s = (f"s{c['streams']}/u{c['unroll']}/"
                 f"n{c['block_n']}/k{c['block_k']}")
        print(f"{r['kernel']:<18}{cfg_s:<26}"
              f"{r['fraction_of_roofline']:>14.3e}"
              f"{r['predicted_fraction']:>10.3f}"
              f"{r['measured_us']:>10.1f}{r['roofline_us']:>10.3f}"
              f"{str(r['from_cache']):>8}")

    payload = {
        "backend": jax.default_backend(),
        "interpret_mode": True,
        "roofline_bytes_per_s": roofline_bw(),
        "peak_flops": PEAK_FLOPS,
        "cache_path": cache.path,
        "kernels": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"\nwrote {args.out} ({len(rows)} kernels; cache: {cache.path})")
    return rows


if __name__ == "__main__":
    main()
