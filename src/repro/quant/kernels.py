"""Fused-dequant GEMV kernels — quantization applied AT the roofline.

``qgemv``/``batched_qgemv`` stream int8 (or packed-int4) weights plus their
per-group scales and dequantize *in register*, between the DMA and the MXU:

  (A) streams=2   — the quantized weight, its scale blocks and x are each
                    fetched as two disjoint contiguous K-halves (independent
                    BlockSpecs -> two DMAs in flight per grid step).
  (C) shadow acc  — fp32 accumulator in VMEM scratch; y commits once per
                    row-tile.
  (D) alignment   — the scale group is a multiple of the int8 layout
                    granule and divides block_k, so each (block_n, block_k)
                    weight tile consumes whole scale blocks: the dequant
                    multiply is one reshape-broadcast on the VPU, never a
                    gather across tile edges (DESIGN.md §5).
  (E) layout      — int4 packs two values per byte along K, so a packed
                    block is still one dense contiguous HBM region.

At OI ~= 1 the runtime bound is bytes/BW, so int8 halves and int4 quarters
the attainable decode-GEMV time — the registered ``bytes=`` models count
the quantized widths *and* the scale traffic, which is what ``repro.tune``
scores fraction-of-roofline against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.quant.tensor import quantize
from repro.tune.registry import itemsize, numel, troop_kernel


def _infer_bits(wq, K: int) -> int:
    """8 if the stored K extent is logical, 4 if nibble-packed (K//2)."""
    if wq.shape[1] == K:
        return 8
    assert wq.shape[1] == K // 2, \
        f"weight K extent {wq.shape[1]} matches neither K={K} (int8) nor " \
        f"K//2={K // 2} (packed int4)"
    return 4


def _dequant_block(w_ref, s_ref, *, bits: int, g: int):
    """(bn, bk[, packed]) int8 + (bn, bk//g) scales -> (bn, bk) fp32."""
    w8 = w_ref[...]
    if bits == 4:
        lo = jnp.right_shift(jnp.left_shift(w8, 4), 4)   # sign-extend
        hi = jnp.right_shift(w8, 4)
        w8 = jnp.stack([lo, hi], axis=-1).reshape(w8.shape[0], -1)
    bn, bk = w8.shape
    s = s_ref[...].astype(jnp.float32)                   # (bn, bk // g)
    w = w8.astype(jnp.float32).reshape(bn, bk // g, g) * s[:, :, None]
    return w.reshape(bn, bk)


def _kernel_1s(w_ref, s_ref, x_ref, o_ref, acc, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    w = _dequant_block(w_ref, s_ref, bits=bits, g=g)
    acc[...] += jnp.dot(w, x_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _kernel_2s(w0, s0, x0, w1, s1, x1, o_ref, acc, *, bits, g):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc[...] = jnp.zeros_like(acc)

    a = jnp.dot(_dequant_block(w0, s0, bits=bits, g=g),
                x0[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    b = jnp.dot(_dequant_block(w1, s1, bits=bits, g=g),
                x1[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    acc[...] += a + b

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _qgemv_2d(wq, scales, x2, cfg: TroopConfig):
    """wq (N, Ks) int8, scales (N, K//g), x2 (K, B) -> (N, B) fp32."""
    N = wq.shape[0]
    K, B = x2.shape
    bits = _infer_bits(wq, K)
    g = K // scales.shape[1]
    pack = 2 if bits == 4 else 1

    bn = min(cfg.block_n, N)
    while N % bn:
        bn //= 2
    streams = cfg.streams if (K // g) % 2 == 0 and cfg.streams == 2 else 1
    Kh = K // streams
    bk = max(min(cfg.block_k * cfg.unroll, Kh) // g * g, g)
    while Kh % bk:
        bk -= g
    steps = Kh // bk
    body = functools.partial(
        _kernel_1s if streams == 1 else _kernel_2s, bits=bits, g=g)

    # block index maps share j: the packed weight, its scale blocks and the
    # x slice advance in lockstep along K (bk elements = bk//pack bytes =
    # bk//g scale entries per step)
    w_lo = pl.BlockSpec((bn, bk // pack), lambda i, j: (i, j))
    w_hi = pl.BlockSpec((bn, bk // pack), lambda i, j, o=steps: (i, j + o))
    s_lo = pl.BlockSpec((bn, bk // g), lambda i, j: (i, j))
    s_hi = pl.BlockSpec((bn, bk // g), lambda i, j, o=steps: (i, j + o))
    x_lo = pl.BlockSpec((bk, B), lambda i, j: (j, 0))
    x_hi = pl.BlockSpec((bk, B), lambda i, j, o=steps: (j + o, 0))

    if streams == 1:
        in_specs, ops = [w_lo, s_lo, x_lo], (wq, scales, x2)
    else:
        in_specs = [w_lo, s_lo, x_lo, w_hi, s_hi, x_hi]
        ops = (wq, scales, x2, wq, scales, x2)
    return pl.pallas_call(
        body,
        grid=(N // bn, steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, B), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, B), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, B), jnp.float32)],
        interpret=cfg.interpret,
    )(*ops)


# --------------------------------------------------------------------------
# registration
# --------------------------------------------------------------------------
def _example(small: bool = True, bits: int = 8, batch: int = 0):
    N, K = (128, 512) if small else (2048, 4096)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(ks[0], (N, K), jnp.float32)
    qt = quantize(w, bits=bits, group_size=128, axis=-1)
    if batch:
        x = jax.random.normal(ks[1], (batch, K), jnp.bfloat16)
    else:
        x = jax.random.normal(ks[1], (K,), jnp.bfloat16)
    return (qt.values, qt.scales, x), {}


def _qgemv_bytes(wq, s, x):
    K = x.shape[-1]
    B = x.shape[0] if len(x.shape) == 2 else 1
    return (numel(wq) * itemsize(wq) + numel(s) * itemsize(s)
            + B * K * itemsize(x) + B * wq.shape[0] * 4)


def _qgemv_streamed(wq, s, x):
    out = (x.shape[0], wq.shape[0]) if len(x.shape) == 2 else (wq.shape[0],)
    return [wq, s, x, jax.ShapeDtypeStruct(out, jnp.float32)]


_QSPACE = {"streams": (1, 2), "unroll": (1, 2),
           "block_n": (128, 256), "block_k": (256, 512)}


@troop_kernel(
    "qgemv",
    flops=lambda wq, s, x: 2.0 * wq.shape[0] * x.shape[0],
    bytes=_qgemv_bytes,
    streamed=_qgemv_streamed,
    space=_QSPACE,
    ref="qgemv", example=_example)
@functools.partial(jax.jit, static_argnames=("cfg",))
def qgemv(wq, scales, x, cfg: TroopConfig = TroopConfig()):
    """Quantized GEMV: wq (N, K | K//2-packed) int8, scales (N, K//g),
    x (K,) -> y (N,) fp32.  Bit width inferred from the packed extent."""
    return _qgemv_2d(wq, scales, x.reshape(-1, 1), cfg).reshape(-1)


@troop_kernel(
    "batched_qgemv",
    flops=lambda wq, s, xs: 2.0 * xs.shape[0] * wq.shape[0] * xs.shape[1],
    bytes=_qgemv_bytes,
    streamed=_qgemv_streamed,
    space=_QSPACE,
    ref="batched_qgemv",
    example=functools.partial(_example, batch=4))
@functools.partial(jax.jit, static_argnames=("cfg",))
def batched_qgemv(wq, scales, xs, cfg: TroopConfig = TroopConfig()):
    """Small-batch decode projection: xs (B, K) -> (B, N) fp32.  The batch
    rides the lane dim of one kernel invocation — the weight stream (the
    roofline term) is unchanged from ``qgemv``."""
    return _qgemv_2d(wq, scales, xs.T, cfg).T
