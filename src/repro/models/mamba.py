"""Mamba-1 selective-SSM block (Jamba's mixer).

Reference path evaluates the selective scan with ``lax.scan`` over time
(exact; oracle for a chunked kernel).  Decode carries an O(1) state:
conv tap history (B, d_inner, d_conv-1) + SSM state (B, d_inner, d_state) —
which is why the hybrid Jamba runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partitioning as PT
from repro.models import modules as M


class MambaState(NamedTuple):
    conv: jax.Array    # (B, d_inner, d_conv-1)
    ssm: jax.Array     # (B, d_inner, d_state) fp32


def _dims(cfg):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return s, di, dt_rank


def mamba_init(key, cfg):
    s, di, dt_rank = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": M.dense_init(ks[0], d, 2 * di, ("embed", "inner")),
        "conv_w": M.Param(0.1 * jax.random.normal(
            ks[1], (di, s.d_conv), jnp.float32), ("inner", None)),
        "conv_b": M.Param(jnp.zeros((di,), jnp.float32), ("inner",)),
        "x_proj": M.dense_init(ks[2], di, dt_rank + 2 * s.d_state,
                               ("inner", None)),
        "dt_proj": M.dense_init(ks[3], dt_rank, di, (None, "inner"),
                                bias=True),
        "A_log": M.Param(jnp.log(A), ("inner", None)),
        "D": M.Param(jnp.ones((di,), jnp.float32), ("inner",)),
        "out_proj": M.dense_init(ks[4], di, d, ("inner", "embed")),
    }


def _ssm_scan(u, dt, B_in, C, A, D, state0):
    """u,dt: (B,T,di); B_in,C: (B,T,ds); A: (di,ds); state (B,di,ds)."""
    u, dt, B_in, C = (a.astype(jnp.float32) for a in (u, dt, B_in, C))
    dA = jnp.exp(dt[..., None] * A[None, None])               # (B,T,di,ds)
    dBu = dt[..., None] * B_in[:, :, None, :] * u[..., None]

    def step(h, x):
        dA_t, dBu_t, C_t = x
        h = dA_t * h + dBu_t
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBu, 1, 0),
          jnp.moveaxis(C, 1, 0))
    h, ys = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + u * D[None, None]
    return y, h


def _causal_conv(x, w, b, history):
    """Depthwise causal conv. x: (B,T,di), w: (di,K), history: (B,di,K-1)."""
    B, T, di = x.shape
    K = w.shape[1]
    xt = jnp.concatenate([jnp.moveaxis(history, 2, 1), x], axis=1)  # (B,T+K-1,di)
    y = sum(xt[:, j:j + T, :] * w[None, None, :, j] for j in range(K))
    new_hist = jnp.moveaxis(xt[:, T:, :], 1, 2) if K > 1 else history
    return y + b[None, None], new_hist


def apply_mamba(p, cfg, x, state: MambaState, dtype):
    s, di, dt_rank = _dims(cfg)
    B, T, d = x.shape
    xz = M.apply_dense(p["in_proj"], x, dtype)
    xs_, z = jnp.split(xz, 2, axis=-1)
    xs_ = PT.constrain(xs_, ("batch", None, "inner"))
    z = PT.constrain(z, ("batch", None, "inner"))
    xs_, conv_hist = _causal_conv(xs_.astype(jnp.float32), p["conv_w"],
                                  p["conv_b"], state.conv)
    xs_ = jax.nn.silu(xs_)
    proj = xs_.astype(dtype) @ p["x_proj"]["w"].astype(dtype)
    dt, B_in, C = jnp.split(proj, [dt_rank, dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) @ p["dt_proj"]["w"]
                         + p["dt_proj"]["b"])
    A = -jnp.exp(p["A_log"])
    y, h = _ssm_scan(xs_, dt, B_in, C, A, p["D"], state.ssm)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = M.apply_dense(p["out_proj"], y.astype(dtype), dtype)
    new_hist = conv_hist[:, :, -(s.d_conv - 1):] if s.d_conv > 1 else state.conv
    return out, MambaState(new_hist.astype(state.conv.dtype), h)


def init_mamba_state(cfg, B: int, dtype) -> MambaState:
    s, di, _ = _dims(cfg)
    return MambaState(jnp.zeros((B, di, s.d_conv - 1), jnp.float32),
                      jnp.zeros((B, di, s.d_state), jnp.float32))
