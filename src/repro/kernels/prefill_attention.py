"""Chunked-prefill attention over a paged KV cache (block-table gather).

The chunked-prefill engine (serve.scheduler) feeds prompts through the model
as fixed-size token slabs; each slab attends causally over everything the
slot has cached so far — including *shared prefix* pages it never computed
(serve.kvcache.PrefixIndex).  This kernel is the at-the-roofline path for
that step: the KV stream is gathered page by page through the scalar-
prefetched block table (mechanism (E) at HBM granularity, exactly as in
``paged_decode_attention``), the query slab rides along in VMEM, and the
causal mask is applied against the slab's absolute ``q_offset`` — so a
prefix-cache hit enters mid-sequence without recomputing a single shared
row.

Like the decode kernels the per-page contractions are batched MXU
dot_generals with online-softmax state in VMEM scratch; ``streams=2`` walks
the two halves of the slot's logical sequence concurrently (odd page counts
fall back to one stream).  Pages the whole slab cannot see (entirely beyond
``q_offset + C``) still stream — the grid is static — but their scores mask
to -inf and contribute exact zeros, preserving bit-identical online-softmax
results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.troop import TroopConfig
from repro.tune.registry import itemsize, troop_kernel

_NEG = -1e30


def _prologue(m_s, l_s, acc):
    m_s[...] = jnp.full_like(m_s, _NEG)
    l_s[...] = jnp.zeros_like(l_s)
    acc[...] = jnp.zeros_like(acc)


def _slab_update(q, k, v, s0, q0, valid, scale, m_s, l_s, acc):
    """One online-softmax update: slab q (C, KV, G, hd) x one cache page
    k/v (page, KV, hd) whose first row sits at absolute position ``s0``."""
    C, KV, G, hd = q.shape
    page = k.shape[0]
    kT = jnp.moveaxis(k, 1, 0).astype(jnp.float32)        # (KV, page, hd)
    vT = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    qr = jnp.moveaxis(q, 1, 0).astype(jnp.float32)        # (KV, C, G, hd)
    s = jax.lax.dot_general(
        qr.reshape(KV, C * G, hd), kT, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale
    s = s.reshape(KV, C, G, page)
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    spos = s0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
    s = jnp.where((spos > qpos) | (spos >= valid), _NEG, s)
    m_new = jnp.maximum(m_s[...], jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_s[...] - m_new)
    p = jnp.exp(s - m_new)                                # (KV, C, G, page)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, -1, keepdims=True)
    pv = jax.lax.dot_general(
        p.reshape(KV, C * G, page), vT, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).reshape(KV, C, G, hd)
    acc[...] = acc[...] * alpha + pv
    m_s[...] = m_new


def _epilogue(o_ref, l_s, acc, dtype):
    out = acc[...] / jnp.maximum(l_s[...], 1e-30)         # (KV, C, G, hd)
    o_ref[0] = jnp.moveaxis(out, 0, 1).astype(dtype)


def _kernel_1s(bt_ref, off_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
               m_s, l_s, acc, *, scale, page):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    _slab_update(q_ref[0], k_ref[0], v_ref[0], j * page, off_ref[b],
                 len_ref[b], scale, m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue(o_ref, l_s, acc, o_ref.dtype))


def _kernel_2s(bt_ref, off_ref, len_ref, q_ref, k0, v0, k1, v1, o_ref,
               m_s, l_s, acc, *, scale, page, half):
    b, j = pl.program_id(0), pl.program_id(1)
    pl.when(j == 0)(lambda: _prologue(m_s, l_s, acc))
    q = q_ref[0]                                          # (C, KV, G, hd)
    q0, valid = off_ref[b], len_ref[b]
    _slab_update(q, k0[0], v0[0], j * page, q0, valid, scale, m_s, l_s, acc)
    _slab_update(q, k1[0], v1[0], (half + j) * page, q0, valid, scale,
                 m_s, l_s, acc)
    pl.when(j == pl.num_programs(1) - 1)(
        lambda: _epilogue(o_ref, l_s, acc, o_ref.dtype))


def _example(small: bool = True):
    import numpy as np
    B, C, H, KV, hd, page, nblk = (2, 16, 4, 2, 128, 16, 4) if small \
        else (4, 64, 16, 8, 128, 16, 16)
    P = 1 + B * nblk
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, C, H, hd), jnp.bfloat16)
    k_pool = jax.random.normal(ks[1], (P, page, KV, hd), jnp.bfloat16)
    v_pool = jax.random.normal(ks[2], (P, page, KV, hd), jnp.bfloat16)
    perm = np.random.default_rng(0).permutation(P - 1) + 1
    bt = jnp.asarray(perm[:B * nblk].reshape(B, nblk), jnp.int32)
    # slab b starts mid-sequence (a prefix-cache hit) and fills to length
    q_offset = jnp.asarray([7 * b for b in range(B)], jnp.int32)
    length = q_offset + C
    return (q, k_pool, v_pool, bt, q_offset, length), {}


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill_attention_paged(q, k_pool, v_pool, block_tables, q_offset,
                             length, cfg: TroopConfig = TroopConfig()):
    B, C, H, hd = q.shape
    page, KV = k_pool.shape[1], k_pool.shape[2]
    nblk = block_tables.shape[1]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, C, KV, G, hd)
    streams = cfg.streams if nblk % 2 == 0 else 1
    half = nblk // streams

    scratch = [pltpu.VMEM((KV, C, G, 1), jnp.float32),
               pltpu.VMEM((KV, C, G, 1), jnp.float32),
               pltpu.VMEM((KV, C, G, hd), jnp.float32)]
    q_spec = pl.BlockSpec((1, C, KV, G, hd),
                          lambda b, j, bt, off, ln: (b, 0, 0, 0, 0))
    out_spec = pl.BlockSpec((1, C, KV, G, hd),
                            lambda b, j, bt, off, ln: (b, 0, 0, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, C, KV, G, hd), q.dtype)
    lo = pl.BlockSpec((1, page, KV, hd),
                      lambda b, j, bt, off, ln: (bt[b, j], 0, 0, 0))
    hi = pl.BlockSpec((1, page, KV, hd),
                      lambda b, j, bt, off, ln, o=half: (bt[b, o + j], 0, 0, 0))

    if streams == 1:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3, grid=(B, nblk),
            in_specs=[q_spec, lo, lo], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            functools.partial(_kernel_1s, scale=scale, page=page),
            grid_spec=grid_spec, out_shape=out_shape,
            interpret=cfg.interpret,
        )(block_tables, q_offset, length, qg, k_pool, v_pool)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3, grid=(B, half),
            in_specs=[q_spec, lo, lo, hi, hi], out_specs=out_spec,
            scratch_shapes=scratch)
        out = pl.pallas_call(
            functools.partial(_kernel_2s, scale=scale, page=page, half=half),
            grid_spec=grid_spec, out_shape=out_shape,
            interpret=cfg.interpret,
        )(block_tables, q_offset, length, qg, k_pool, v_pool, k_pool, v_pool)
    return out.reshape(B, C, H, hd)


def _streamed(q, kp, vp, bt, off, ln):
    """Per-slot page traffic + the slab in/out + the table.  Shared prefix
    pages are counted by their block-table entries here (this kernel really
    does stream them per slot); the *residency* dedup — each physical page
    once — is the serve layer's accounting (kvcache.kv_page_bytes)."""
    view = (q.shape[0], bt.shape[1] * kp.shape[1], kp.shape[2], kp.shape[3])
    return [jax.ShapeDtypeStruct(view, kp.dtype),
            jax.ShapeDtypeStruct(view, vp.dtype), q, q, bt]


@troop_kernel(
    "prefill_attention_paged",
    flops=lambda q, kp, vp, bt, off, ln: (
        4.0 * q.shape[0] * q.shape[1] * q.shape[2] * q.shape[3]
        * bt.shape[1] * kp.shape[1]),
    bytes=lambda q, kp, vp, bt, off, ln: (
        q.shape[0] * bt.shape[1] * kp.shape[1] * kp.shape[2] * kp.shape[3]
        * (itemsize(kp) + itemsize(vp))
        + 2 * q.shape[0] * q.shape[1] * q.shape[2] * q.shape[3] * itemsize(q)
        + bt.shape[0] * bt.shape[1] * itemsize(bt)),
    streamed=_streamed,
    space={"streams": (1, 2)},
    ref="prefill_attention_paged", example=_example)
def prefill_attention_paged(q, k_pool, v_pool, block_tables, q_offset,
                            length, cfg: TroopConfig = TroopConfig()):
    """Causal chunk attention over a paged KV cache.

    q (B,C,H,hd) — a prefill slab whose row 0 sits at absolute position
    ``q_offset`` (B,); k_pool/v_pool (P,page,KV,hd); block_tables (B,nblk);
    ``length`` (B,) = q_offset + valid rows (positions >= length are
    masked).  Returns (B,C,H,hd) in q.dtype; rows past the valid count are
    garbage (their positions exceed ``length``) and must be discarded by
    the caller, exactly as the bucketed prefill discards pad rows.
    """
    return _prefill_attention_paged(q, k_pool, v_pool, block_tables,
                                    q_offset, length, cfg)
