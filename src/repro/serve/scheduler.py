"""Continuous-batching serving engine (paged KV; chunked or bucketed prefill).

The decode step — the paper's workload — runs every cycle over all active
slots.  Two recompile-free admission paths:

  * **bucketed** (the PR 2 path, default): queued prompts are padded to
    power-of-2 length buckets and prefilled together in one fixed-size
    batch — one XLA prefill executable per bucket, ever.  A long prompt
    still occupies the engine for its whole prefill, head-of-line-blocking
    running decodes.
  * **chunked** (``chunked_prefill=True``, paged backend only): prompts are
    fed through the model as fixed-size token slabs *interleaved with
    decode steps* — ONE compiled prefill shape total (no buckets), new
    requests admitted every cycle, and a 4k-token prompt costs each running
    decode at most one chunk of latency per cycle instead of a full-prompt
    stall.  With ``prefix_cache=True`` the paged pool additionally shares
    prompt prefixes across requests (radix index + refcounted pages +
    copy-on-write at a mid-page divergence — ``serve.kvcache``), and a
    prefix hit starts the chunk walk at the first un-cached token.

Scheduling policy (the fairness / starvation guard): admission, chunk
order and capacity-pressure deferral are all strictly FIFO — a request
that cannot reserve pages blocks the queue rather than being overtaken,
so under sustained load every request admits in bounded time; each cycle
runs at most ``chunks_per_step`` prefill slabs *and then* one decode step
over every decoding slot, so neither phase can starve the other.

Cache placement goes through a ``CacheBackend`` (``serve.kvcache``); pure
host-side control around jitted step functions, as production engines do.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import speculate as SP
from repro.serve.config import EngineConfig
from repro.serve.kvcache import (CacheBackend, PagedBackend, bucket_length,
                                 copy_page, kv_row_bytes, make_backend,
                                 resolve_kv_dtype, splice_row)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (T,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # lifecycle metadata (filled by the engine; *_step counters are engine
    # cycles — deterministic for a seeded trace, the basis of the CI SLO
    # bands — while *_t markers are wall-clock perf_counter seconds)
    submit_step: int = -1
    admit_step: int = -1
    first_token_step: int = -1
    finish_step: int = -1
    submit_t: float = 0.0
    admit_t: float = 0.0
    first_token_t: float = 0.0
    finish_t: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def ttft_s(self) -> float:
        """Time to first token: submit -> first generated token."""
        return self.first_token_t - self.submit_t

    @property
    def decode_tok_s(self) -> float:
        """Steady-state decode rate: tokens after the first, per second."""
        dt = self.finish_t - self.first_token_t
        return (len(self.out) - 1) / dt if dt > 0 and len(self.out) > 1 \
            else 0.0

    @property
    def tpot_s(self) -> float:
        """Time per output token after the first (1 / decode_tok_s)."""
        r = self.decode_tok_s
        return 1.0 / r if r else 0.0


def splice_cache(batch_cache, one_cache, slot: int, slots: int):
    """Insert a B=1 prefill cache into slot ``slot`` of the batch cache
    (compat shim over ``kvcache.splice_row``; the engine itself splices
    through its ``CacheBackend``)."""
    return jax.tree.map(
        lambda dst, src: splice_row(dst, src, 0, slot, slots),
        batch_cache, one_cache)


class ServingEngine:
    """Slot-based continuous batching over a pluggable cache backend.

    ``backend``: 'dense' (default, the original layout), 'paged', or a
    ``CacheBackend`` instance.  Bucketed mode: ``prefill_batch`` admissions
    share one bucketed prefill call; ``min_bucket`` is the smallest prompt
    bucket.  Chunked mode (``chunked_prefill=True``): prompts prefill as
    ``chunk_size``-token slabs interleaved with decode (attention-only
    archs over the paged backend); ``prefix_cache=True`` additionally
    reuses shared prompt-prefix pages (``chunk_step`` overrides the
    default ``serve.step.make_chunk_step(model)``).
    """

    def __init__(self, model, *, params,
                 config: Optional[EngineConfig] = None,
                 prefill_step=None, serve_step=None,
                 prefill_extras=None, backend=None, chunk_step=None,
                 tracer=None, profiler=None,
                 draft_model=None, draft_params=None, **legacy):
        """``config``: an ``EngineConfig`` — the primary constructor path
        (``repro.serve.build_engine`` is the one factory).  The legacy
        loose keywords (``slots=``, ``cache_len=``, ...) keep working for
        one release through a shim that emits a ``DeprecationWarning`` and
        forwards into ``EngineConfig.from_legacy_kwargs`` (DESIGN.md §10);
        speculative-decoding options live ONLY on the config.

        ``draft_model`` / ``draft_params`` (required when
        ``config.speculate_k > 0``): the draft half of the speculative
        pair, run over its own private paged cache.

        ``prefill_extras(req) -> dict``: extra prefill batch entries
        (modality frontend stubs for enc-dec / VLM archs).  ``tracer``: a
        ``repro.obs.Tracer`` fed with per-request lifecycle spans and
        allocator events (None: zero overhead).  ``metrics_window`` bounds
        the per-request latency samples ``metrics()`` aggregates so a
        long-lived engine never grows without bound.

        ``tp > 1`` runs every jitted step under ``shard_map`` over a 1-D
        tensor-parallel mesh (``repro.dist.tp``): attention heads / ffn
        dims / MoE experts shard across devices and the KV page pools
        shard on the head axis, while block tables, the prefix index and
        the allocator stay host-side and replicated.  ``tp_mode``:
        ``"exact"`` (token-identical to tp=1) or ``"overlap"`` (ring
        collectives from ``repro.dist.collective_matmul``; tolerance-equal).
        ``async_dispatch`` (default): the decode step submitted in cycle N
        is consumed at the start of cycle N+1, so host-side scheduling work
        overlaps the in-flight device step (one-step-deep pipeline).

        ``profiler``: a ``repro.obs.DispatchProfiler`` — the engine
        brackets every step submission in a phase context (``admit`` /
        ``bucketed_prefill`` / ``chunk_prefill`` / ``decode`` /
        ``collective`` under TP) so registry-kernel dispatches and
        measured step walls aggregate per phase (None: zero overhead)."""
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either config=EngineConfig(...) or the legacy "
                    f"keywords, not both (got {sorted(legacy)})")
            warnings.warn(
                "ServingEngine(slots=..., cache_len=..., ...) keyword "
                "construction is deprecated — pass config=EngineConfig(...)"
                " or build via repro.serve.build_engine (DESIGN.md §10)",
                DeprecationWarning, stacklevel=2)
            config = EngineConfig.from_legacy_kwargs(**legacy)
        elif config is None:
            config = EngineConfig()
        self.backend: CacheBackend = make_backend(
            backend if backend is not None else config.backend)
        # a passed-in backend instance wins over config.backend: normalize
        # the record to what actually runs, then cross-validate
        config = dataclasses.replace(config, backend=self.backend.name)
        config.validate()
        self.config = config
        slots, cache_len = config.slots, config.cache_len

        if prefill_step is None or serve_step is None:
            from repro.serve.step import make_prefill_step, make_serve_step
            if prefill_step is None:
                prefill_step = make_prefill_step(model)
            if serve_step is None:
                serve_step = make_serve_step(
                    model, temperature=config.temperature, seed=config.seed)

        self.model = model
        self.tracer = tracer
        self.profiler = profiler
        self.slots = slots
        self.cache_len = cache_len
        self.params = params
        self.prefill_extras = prefill_extras
        self.backend.tracer = tracer       # allocator/prefix/COW events
        # the ONE kv-storage-dtype resolution (DESIGN.md §10): an explicit
        # backend kv_dtype wins, else the model's rt.kv_dtype() alias is
        # collapsed here — every downstream consumer (chunk staging, the
        # streamed-bytes model, the backend pools) reads this value
        self.kv_dtype = (getattr(self.backend, "kv_dtype", None)
                         or resolve_kv_dtype(model))
        if isinstance(self.backend, PagedBackend) \
                and self.backend.kv_dtype is None:
            self.backend.kv_dtype = self.kv_dtype
        self.prefill_batch = config.prefill_batch or min(slots, 4)
        self.min_bucket = min(config.min_bucket, cache_len)
        self.chunked = config.chunked_prefill
        self.chunk_size = min(config.chunk_size, cache_len)
        self.chunks_per_step = max(1, config.chunks_per_step)
        # frontend tokens prepended to the decoder sequence (VLM archs)
        self._front = model.cfg.frontend_tokens \
            if getattr(model.cfg, "frontend", None) == "vision" else 0
        # right-padding a prompt is exact only for causal attention: a
        # recurrent mixer (mamba/rwkv) scans THROUGH pad tokens and hands
        # decode a polluted state — those archs prefill at exact length
        # (same-length prompts still batch; compiles are per length, as in
        # the seed engine, instead of per bucket)
        self._exact_prefill = any(
            m != "attn" for (m, f) in model.cfg.layer_kinds())

        if self.chunked:
            if not isinstance(self.backend, PagedBackend):
                raise ValueError("chunked_prefill requires the paged "
                                 "backend (slabs write through block "
                                 "tables)")
            if (self._exact_prefill or self._front
                    or model.cfg.encoder_decoder
                    or model.cfg.attention == "mla"):
                raise ValueError(
                    "chunked_prefill supports causal-attention decoder "
                    "archs only (recurrent mixers cannot resume a scan "
                    "mid-prompt from pages; MLA/enc-dec keep dense "
                    "caches) — use the bucketed engine for "
                    f"{model.cfg.name!r}")
            self.backend.prefix_cache = (config.prefix_cache
                                         or self.backend.prefix_cache)
            if self.kv_dtype == "int8":
                # int8 pools: stage this request's own rows in bf16 so a
                # later chunk never re-reads its predecessors quantized
                self.backend.chunk_stage = self.chunk_size
        elif config.prefix_cache:
            raise ValueError("prefix_cache requires chunked_prefill (a "
                             "prefix hit resumes prefill mid-prompt, which "
                             "only the chunk walk supports)")

        # --------------------------------------------------- tensor parallel
        tp = config.tp
        self.tp = tp
        self.tp_mode = config.tp_mode
        self._async = bool(config.async_dispatch)
        self._tpx = None
        self._kv_shards = 1
        if tp > 1:
            from repro.dist.tp import TPExecutor
            self._tpx = TPExecutor(model, tp, mode=config.tp_mode)
            self._tpx.profiler = profiler
            self._kv_shards = self._tpx.plan.kv_shards
            self.params = self._tpx.shard_params(model, params)

        self._prefill_traces = 0

        def counted_prefill(params, batch):
            self._prefill_traces += 1      # runs at trace time only
            return prefill_step(params, batch)

        if self._tpx is not None:
            # probe = the uncounted twin: jit_step's one eval_shape must not
            # inflate the compile counter
            self.prefill_step = self._tpx.jit_step(counted_prefill,
                                                   probe=prefill_step)
            self.serve_step = self._tpx.jit_step(serve_step, donate=2)
        else:
            self.prefill_step = jax.jit(counted_prefill)
            self.serve_step = jax.jit(serve_step, donate_argnums=(2,))
        if self.chunked:
            if chunk_step is None:
                from repro.serve.step import make_chunk_step
                chunk_step = make_chunk_step(model)

            def counted_chunk(params, batch, caches):
                self._prefill_traces += 1  # runs at trace time only
                return chunk_step(params, batch, caches)

            if self._tpx is not None:
                self.chunk_step = self._tpx.jit_step(counted_chunk,
                                                     probe=chunk_step,
                                                     donate=2)
            else:
                self.chunk_step = jax.jit(counted_chunk, donate_argnums=(2,))
            self._copy_page = jax.jit(copy_page, donate_argnums=(0,))
        self.caches = self.backend.init_caches(model, slots, cache_len)
        if self._tpx is not None:
            self.caches = self._tpx.shard_caches(self.caches)
            self.backend.tp = tp
            self.backend.kv_shards = self._kv_shards
        # streamed-bytes model (DESIGN.md §8): decode reads every cached
        # row of every decoding slot once per step; a head-sharded pool
        # streams 1/kv_shards of each row per device
        self._kv_row_bytes = kv_row_bytes(model.cfg, self.kv_dtype)

        # ------------------------------------------- speculative decoding
        # (DESIGN.md §10) the FLOP-side roofline lever: a draft model
        # proposes k tokens per cycle, one target verify pass scores all
        # k+1 positions through the chunked slab path, and the host
        # accept/reject rule (serve.speculate) emits 1..k+1 tokens.
        self.spec_k = config.speculate_k
        self.draft_model = draft_model
        self.draft_steps = 0               # draft forward passes
        self.verify_passes = 0             # target verify passes
        self.draft_tokens_proposed = 0
        self.draft_tokens_accepted = 0
        self.spec_tokens_emitted = 0
        self.spec_slot_passes = 0          # per-slot verify scorings
        self.rollback_pages = 0            # lookahead pages freed
        if self.spec_k:
            if draft_model is None or draft_params is None:
                raise ValueError(
                    "speculate_k > 0 needs draft_model + draft_params — "
                    "build the engine via repro.serve.build_engine")
            from repro.serve.step import make_draft_step, make_verify_step
            # the verify pass consumes its emissions synchronously (the
            # accept/reject rule needs the logits on the host)
            self._async = False
            self._draft_W = self.spec_k + 1
            self._temperature = config.temperature
            # the draft runs over its own full-occupancy paged pool (same
            # page size, reservations never fail) sized for the deepest
            # chain the bookkeeping can reach past the target's horizon
            self._draft_cache_len = cache_len + 2 * self._draft_W
            self._draft_backend = PagedBackend(
                page_size=self.backend.page_size)
            self._draft_backend.tracer = tracer
            self.draft_params = draft_params
            self.draft_caches = self._draft_backend.init_caches(
                draft_model, slots, self._draft_cache_len)
            self.draft_step = jax.jit(make_draft_step(draft_model),
                                      donate_argnums=(2,))
            self.verify_step = jax.jit(make_verify_step(model),
                                       donate_argnums=(2,))
            self._spec_rng = np.random.default_rng(config.seed)
            self._draft_pos = np.zeros((slots,), np.int32)
            # tokens emitted by the target that the draft model has not
            # ingested yet (flushed as the first chain step of each cycle)
            self._draft_pending: Dict[int, List[int]] = {}
            self._draft_ready: set = set()   # slots the draft caught up on
        self.active: Dict[int, Optional[Request]] = {
            i: None for i in range(slots)}
        self.pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        # per-admission nonce: a request reusing a slot must not replay its
        # predecessor's sampling randomness at equal positions
        self._nonce = np.zeros((slots,), np.int32)
        self.queue: deque = deque()
        self.stop_token = config.stop_token
        self.steps = 0                     # engine cycles (admit/chunk/decode)
        self.decode_steps = 0              # cycles that ran serve_step
        # chunked-prefill bookkeeping
        self._prefilling: deque = deque()            # slots mid-prefill
        self._decoding: set = set()                  # slots generating
        self._chunk_off: Dict[int, int] = {}         # next token to prefill
        self._stage_base: Dict[int, int] = {}        # first non-shared pos
        # ------------------------------------------------------- metrics
        # _admission_seq is the nonce source and NEVER resets (a reset
        # nonce would replay a previous request's sampling randomness);
        # everything below it is a resettable window (reset_metrics).
        self._admission_seq = 0
        self.tokens_generated = 0
        self.requests_admitted = 0
        self.requests_finished = 0
        self.deferrals = 0                 # cycles a request sat pool-blocked
        self.prefill_calls = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.chunk_calls = 0
        self.chunk_tokens = 0                        # valid slab rows
        self.prefill_tokens = 0                      # admitted prompt tokens
        self.shared_tokens = 0                       # served from the prefix
        # async dispatch: the parked decode step (futures + slot snapshot +
        # submit timestamps) and its overlap accounting
        self._inflight = None
        self.kv_bytes_streamed = 0                   # modeled, all devices
        self.kv_bytes_streamed_per_device = 0        # modeled, one device
        self.host_overlap_s = 0.0      # host work while a step is in flight
        self.stream_wait_s = 0.0       # blocked in stream-out (np.asarray)
        # bounded latency samples: a soak appends one entry per finished
        # request; the deque keeps the trailing window only
        self._ttfts: deque = deque(maxlen=config.metrics_window)
        self._decode_rates: deque = deque(maxlen=config.metrics_window)

    @property
    def prefill_traces(self) -> int:
        """Prefill executables compiled so far (bucketed: == distinct
        buckets used; chunked: exactly one, ever)."""
        return self._prefill_traces

    # -------------------------------------------------------------- admit
    def submit(self, req: Request):
        # impossible requests fail HERE, loudly — once queued, a request is
        # only ever deferred (transient pool pressure), never dropped
        rows = self._front + req.prompt_len
        if rows >= self.cache_len:
            raise ValueError(
                f"prompt needs {rows} cache rows (incl. frontend) but "
                f"cache_len is {self.cache_len}")
        self.backend.check_admissible(rows + req.max_new_tokens)
        req.submit_step = self.steps
        req.submit_t = time.perf_counter()
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.instant("submit", "queue", rid=req.rid,
                                prompt_len=req.prompt_len,
                                max_new=req.max_new_tokens,
                                queue_depth=len(self.queue))

    def _free_slots(self) -> List[int]:
        return [s for s, r in self.active.items() if r is None]

    def _phase(self, name: str, key=None):
        """Profiler phase context for one engine step (no-op without a
        profiler — one attr check, like the tracer sites)."""
        if self.profiler is None:
            return contextlib.nullcontext()
        return self.profiler.phase(name, key=key)

    def _admit_group(self, group, slots_for) -> List[Request]:
        """One bucketed batched prefill for ``group`` (list of Requests);
        returns requests whose prefill-emitted first token already finished
        them (stop token, or max_new_tokens == 1)."""
        if self._exact_prefill:
            bucket = group[0].prompt_len       # group is same-length
        else:
            bucket = max(bucket_length(r.prompt_len, self.min_bucket,
                                       self.cache_len) for r in group)
        Bp = self.prefill_batch
        tokens = np.zeros((Bp, bucket), np.int32)
        lengths = np.ones((Bp,), np.int32)
        for i, req in enumerate(group):
            tokens[i, :req.prompt_len] = req.prompt
            lengths[i] = self._front + req.prompt_len
        batch = {"tokens": jnp.asarray(tokens),
                 "length": jnp.asarray(lengths)}
        if self.prefill_extras is not None:
            extras: Dict[str, Any] = {}
            per_req = [self.prefill_extras(r) for r in group]
            for k in per_req[0]:
                rows = [e[k] for e in per_req]
                rows += [rows[-1]] * (Bp - len(rows))   # pad batch rows
                extras[k] = jnp.concatenate(rows, axis=0)
            batch.update(extras)

        t0 = time.perf_counter()
        with self._phase("bucketed_prefill", key=bucket):
            next_tok, prefill_caches = self.prefill_step(self.params, batch)
            next_tok = np.asarray(next_tok)
        self.prefill_calls += 1
        if self.tracer is not None:
            self.tracer.span("prefill", "engine", self.tracer.rel(t0),
                             self.tracer.now(), bucket=bucket,
                             batch=len(group))

        finished: List[Request] = []
        for i, req in enumerate(group):
            slot = slots_for[i]
            plen = self._front + req.prompt_len
            self.caches = self.backend.admit(
                self.caches, prefill_caches, row=i, slot=slot,
                prompt_len=plen)
            self.active[slot] = req
            req.admit_step = self.steps
            req.admit_t = time.perf_counter()
            self.requests_admitted += 1
            self._admission_seq += 1
            self.prefill_tokens += req.prompt_len
            self._nonce[slot] = self._admission_seq
            self.pos[slot] = plen
            if self.tracer is not None:
                self.tracer.instant("admit", slot, rid=req.rid,
                                    prompt_len=req.prompt_len,
                                    wait_steps=self.steps - req.submit_step)
            tok = int(next_tok[i])
            req.out.append(tok)
            req.first_token_step = self.steps
            req.first_token_t = time.perf_counter()
            if self.tracer is not None:
                self.tracer.instant("first_token", slot, rid=req.rid,
                                    ttft_steps=self.steps - req.submit_step)
            self.tokens_generated += 1
            self.last_tok[slot] = tok
            # the first token obeys the same finish rules as decode tokens
            # (both prefill paths must emit identical streams)
            if len(req.out) >= req.max_new_tokens or tok == self.stop_token:
                finished.append(self._finish(slot, req))
            else:
                self._decoding.add(slot)
        self.prefill_s += time.perf_counter() - t0
        return finished

    def _admit(self) -> List[Request]:
        """Admit as many queued requests as slots + cache capacity allow
        (possibly several bucketed prefill calls); returns requests their
        first token already finished."""
        finished: List[Request] = []
        while self.queue:
            free = self._free_slots()
            if not free:
                break
            with self._phase("admit"):
                group, slots_for = self._gather_group(free)
            if not group:
                break
            finished.extend(self._admit_group(group, slots_for))
        return finished

    def _gather_group(self, free):
        """Pop a bucketed-prefill admission group off the queue."""
        group, slots_for = [], []
        while (self.queue and free
               and len(group) < self.prefill_batch):
            req = self.queue[0]
            if self._exact_prefill and group \
                    and req.prompt_len != group[0].prompt_len:
                break                      # exact-length groups only
            slot = free[0]
            need = self._front + req.prompt_len + req.max_new_tokens
            if not self.backend.reserve(slot, need):
                self._defer(req, need)
                break                  # pool exhausted: defer admission
            self.queue.popleft()
            free.pop(0)
            group.append(req)
            slots_for.append(slot)
        return group, slots_for

    # ------------------------------------------------- chunked admission
    def _admit_chunked(self, count_defer: bool = True):
        """Assign slots + pages to queued requests, strictly FIFO: a
        request the pool cannot hold right now *blocks* admission (no
        overtaking — the starvation guard) until releases free pages."""
        with self._phase("admit"):
            self._admit_chunked_locked(count_defer)

    def _admit_chunked_locked(self, count_defer: bool):
        while self.queue:
            free = self._free_slots()
            if not free:
                return
            req = self.queue[0]
            slot = free[0]
            need = req.prompt_len + req.max_new_tokens
            if self.backend.prefix_cache:
                offset = self.backend.reserve_with_prefix(
                    slot, need, req.prompt)
                if offset is None:
                    self._defer(req, need, count=count_defer)
                    return                 # pool exhausted: defer (FIFO)
                cow = self.backend.take_cow(slot)
                if cow is not None:
                    src, dst = cow
                    self.caches = self._copy_page(
                        self.caches, jnp.int32(src), jnp.int32(dst))
                    self.backend.cow_done(slot)
            else:
                if not self.backend.reserve(slot, need):
                    self._defer(req, need, count=count_defer)
                    return
                offset = 0
            self.queue.popleft()
            self.active[slot] = req
            req.admit_step = self.steps
            req.admit_t = time.perf_counter()
            self.requests_admitted += 1
            self._admission_seq += 1
            self.prefill_tokens += req.prompt_len
            self.shared_tokens += offset
            self._nonce[slot] = self._admission_seq
            self.pos[slot] = 0
            self._chunk_off[slot] = offset
            self._stage_base[slot] = offset
            self._prefilling.append(slot)
            if self.tracer is not None:
                self.tracer.instant("admit", slot, rid=req.rid,
                                    prompt_len=req.prompt_len,
                                    prefix_offset=offset,
                                    wait_steps=self.steps - req.submit_step)

    def _defer(self, req: Request, need: int, count: bool = True):
        """Head-of-queue request cannot reserve pages this cycle.  The
        async early-admission pass passes ``count=False``: it retries after
        the in-flight decode is consumed, and only the retry counts — so
        deferral totals match the synchronous engine."""
        if not count:
            return
        self.deferrals += 1
        if self.tracer is not None:
            self.tracer.instant("defer", "queue", rid=req.rid,
                                need_tokens=need)

    def _chunk_one(self) -> List[Request]:
        """Run one prefill slab for the oldest mid-prefill request; on the
        prompt's final slab, emit its first token (greedy argmax of the
        last valid row — the bucketed engine's readout)."""
        slot = self._prefilling[0]
        req = self.active[slot]
        C = self.chunk_size
        off = self._chunk_off[slot]
        end = min(off + C, req.prompt_len)
        valid = end - off
        tokens = np.zeros((1, C), np.int32)
        tokens[0, :valid] = req.prompt[off:end]
        batch = {
            "tokens": jnp.asarray(tokens),
            "offset": jnp.asarray([off], jnp.int32),
            "valid": jnp.asarray([valid], jnp.int32),
            "stage_base": jnp.asarray([self._stage_base[slot]], jnp.int32),
            "block_tables": jnp.asarray(
                self.backend.block_tables[slot:slot + 1]),
        }
        t0 = time.perf_counter()
        with self._phase("chunk_prefill"):
            next_tok, self.caches = self.chunk_step(
                self.params, batch, self.caches)
        self.prefill_s += time.perf_counter() - t0
        self.chunk_calls += 1
        self.chunk_tokens += valid
        self._chunk_off[slot] = end
        if self.tracer is not None:
            self.tracer.span("chunk", slot, self.tracer.rel(t0),
                             self.tracer.now(), rid=req.rid, off=off,
                             valid=valid)
        if end < req.prompt_len:
            return []
        # prompt fully on-pool: index its pages for prefix reuse, start
        # decoding from its first generated token
        self._prefilling.popleft()
        if self.backend.prefix_cache:
            self.backend.register_prefix(slot, req.prompt)
        self.prefill_calls += 1
        tok = int(np.asarray(next_tok)[0])
        req.out.append(tok)
        req.first_token_step = self.steps
        req.first_token_t = time.perf_counter()
        if self.tracer is not None:
            self.tracer.instant("first_token", slot, rid=req.rid,
                                ttft_steps=self.steps - req.submit_step)
        self.tokens_generated += 1
        self.last_tok[slot] = tok
        self.pos[slot] = req.prompt_len
        if len(req.out) >= req.max_new_tokens or tok == self.stop_token:
            return [self._finish(slot, req)]
        self._decoding.add(slot)
        return []

    def _finish(self, slot: int, req: Request) -> Request:
        req.done = True
        req.finish_step = self.steps
        req.finish_t = time.perf_counter()
        self.active[slot] = None
        self._decoding.discard(slot)
        self.backend.release(slot)
        if self.spec_k and slot in self._draft_ready:
            self._draft_backend.release(slot)
            self._draft_ready.discard(slot)
            self._draft_pending.pop(slot, None)
        self.requests_finished += 1
        # latency samples: only requests that actually emitted a first
        # token have a TTFT, and only multi-token requests have a decode
        # rate — a request finished without either (e.g. truncated before
        # generating) would record a negative ttft_s / a 0.0 rate and drag
        # every mean and percentile
        if req.out and req.first_token_t > 0.0:
            self._ttfts.append(req.ttft_s)
        if len(req.out) > 1 and req.finish_t > req.first_token_t:
            self._decode_rates.append(req.decode_tok_s)
        if self.tracer is not None:
            self.tracer.instant("finish", slot, rid=req.rid,
                                generated=len(req.out),
                                total_steps=self.steps - req.submit_step)
            if req.admit_t > 0.0:
                self.tracer.span("request", slot,
                                 self.tracer.rel(req.admit_t),
                                 self.tracer.rel(req.finish_t), rid=req.rid,
                                 prompt_len=req.prompt_len,
                                 generated=len(req.out))
        return req

    # -------------------------------------------------------------- decode
    def _decode_block_tables(self):
        """Block tables for the decode batch.  Chunked mode masks slots
        that are not decoding (idle or mid-prefill) to the NULL page: the
        decode step computes garbage rows for them regardless, and this
        keeps their scatter writes off live pages — in particular off a
        mid-prefill slot's freshly written slabs."""
        bt = self.backend.block_tables
        if not self.chunked:
            return jnp.asarray(bt)
        mask = np.zeros((self.slots, 1), bt.dtype)
        for s in self._decoding:
            mask[s] = 1
        return jnp.asarray(bt * mask)

    def _submit_decode(self):
        """Enqueue one decode step over the decoding slots and return
        without blocking (JAX async dispatch): the device futures, the
        decoding-slot snapshot and the submit timestamps park in
        ``_inflight`` until ``_consume`` streams the tokens out."""
        batch = {"tokens": jnp.asarray(self.last_tok[:, None]),
                 "pos": jnp.asarray(self.pos),
                 "sample_nonce": jnp.asarray(self._nonce)}
        extras = self.backend.batch_extras()
        if "block_tables" in extras:
            extras["block_tables"] = self._decode_block_tables()
        batch.update(extras)
        t0 = time.perf_counter()
        with self._phase("decode"):
            next_tok, self.caches = self.serve_step(
                self.params, batch, self.caches)
        t_sub = time.perf_counter()
        # streamed-bytes model: this step reads every cached row of every
        # decoding slot once; a head-sharded pool streams 1/kv_shards of
        # each row per device
        rows = int(sum(int(self.pos[s]) + 1 for s in self._decoding))
        self.kv_bytes_streamed += rows * self._kv_row_bytes
        self.kv_bytes_streamed_per_device += rows * (
            self._kv_row_bytes // max(self._kv_shards, 1))
        if self.tracer is not None:
            self.tracer.span("device_submit", "engine", self.tracer.rel(t0),
                             self.tracer.rel(t_sub),
                             batch=len(self._decoding))
        self._inflight = (next_tok, tuple(sorted(self._decoding)), t0, t_sub)

    def _consume(self) -> List[Request]:
        """Block on the in-flight decode step's tokens (the engine's only
        ``block_until_ready`` point) and apply them to the slots that were
        decoding at submit time."""
        if self._inflight is None:
            return []
        next_tok, slots, t0, t_sub = self._inflight
        self._inflight = None
        t_wait = time.perf_counter()
        toks = np.asarray(next_tok)[:, 0]          # stream-out: blocks
        t_done = time.perf_counter()
        # host work done between submit and here overlapped the device
        # step — but only the async pipeline actually interleaves any;
        # the sync path consumes immediately and must report ~0 overlap
        if self._async:
            self.host_overlap_s += max(0.0, t_wait - t_sub)
        self.stream_wait_s += t_done - t_wait
        if self.profiler is not None:
            # the submit span landed in the decode phase; the stream-out
            # wait is the rest of the step's measured wall
            self.profiler.add_wall("decode", t_done - t_wait)
        # decode_s counts host time attributable to decode (submit + wait,
        # not the overlapped window) so prefill_s + decode_s ~= wall time
        self.decode_s += (t_sub - t0) + (t_done - t_wait)
        self.decode_steps += 1
        if self.tracer is not None:
            self.tracer.span("stream_out", "engine", self.tracer.rel(t_wait),
                             self.tracer.rel(t_done), batch=len(slots))
            self.tracer.span("decode", "engine", self.tracer.rel(t0),
                             self.tracer.rel(t_done), batch=len(slots))
        finished: List[Request] = []
        for slot in slots:
            req = self.active[slot]
            tok = int(toks[slot])
            req.out.append(tok)
            self.tokens_generated += 1
            self.last_tok[slot] = tok
            self.pos[slot] += 1
            if len(req.out) >= req.max_new_tokens or tok == self.stop_token \
                    or self.pos[slot] >= self.cache_len - 1:
                finished.append(self._finish(slot, req))
        return finished

    # ------------------------------------------------- speculative decode
    def _draft_forward(self, feed: Dict[int, List[int]],
                       offsets: Dict[int, int]) -> np.ndarray:
        """One batched draft slab: ``feed[slot]`` tokens are written into
        the draft cache at ``offsets[slot]`` and each slot's last-valid-row
        fp32 logits come back (B, V).  Inactive rows run with valid=0
        against NULL-masked block tables (their scatter writes land on the
        scratch page) and are ignored on the host."""
        W = self._draft_W
        bt = self._draft_backend.block_tables
        tokens = np.zeros((self.slots, W), np.int32)
        valid = np.zeros((self.slots,), np.int32)
        offs = np.zeros((self.slots,), np.int32)
        mask = np.zeros((self.slots, 1), bt.dtype)
        for s, toks in feed.items():
            tokens[s, :len(toks)] = toks
            valid[s] = len(toks)
            offs[s] = offsets[s]
            mask[s] = 1
        batch = {"tokens": jnp.asarray(tokens),
                 "offset": jnp.asarray(offs),
                 "valid": jnp.asarray(valid),
                 "stage_base": jnp.zeros((self.slots,), jnp.int32),
                 "block_tables": jnp.asarray(bt * mask)}
        with self._phase("draft"):
            logits, self.draft_caches = self.draft_step(
                self.draft_params, batch, self.draft_caches)
            logits = np.asarray(logits)
        self.draft_steps += 1
        return logits

    def _draft_catchup(self, slot: int):
        """Walk ``slot``'s prompt through the draft model in W-token slabs
        (the draft's own chunked prefill).  Afterwards the draft cache
        covers the prompt and the target's first emission waits in
        ``_draft_pending`` — flushed as step 1 of the next chain."""
        req = self.active[slot]
        if not self._draft_backend.reserve(slot, self._draft_cache_len):
            raise RuntimeError("draft pool exhausted — it is sized for "
                               "full occupancy, so this cannot happen")
        W = self._draft_W
        prompt = [int(t) for t in req.prompt]
        t0 = time.perf_counter()
        off = 0
        while off < len(prompt):
            end = min(off + W, len(prompt))
            self._draft_forward({slot: prompt[off:end]}, {slot: off})
            off = end
        self.prefill_s += time.perf_counter() - t0
        self._draft_pos[slot] = len(prompt)
        self._draft_pending[slot] = [int(self.last_tok[slot])]
        self._draft_ready.add(slot)
        if self.tracer is not None:
            self.tracer.instant("draft_catchup", slot, rid=req.rid,
                                tokens=len(prompt))

    def _spec_cycle(self) -> List[Request]:
        """One speculative cycle over the decoding slots, replacing the
        plain decode step: linear draft chain (k proposals per slot), ONE
        target verify pass scoring all k+1 positions (the TROOP lever —
        every byte of target weights/KV streamed does up to (k+1)x work),
        host accept/reject (``serve.speculate``; greedy mode is
        token-identical to ``_consume``), then page rollback of the
        rejected lookahead tail."""
        for s in sorted(self._decoding):
            if s not in self._draft_ready:
                self._draft_catchup(s)
        slots = tuple(sorted(self._decoding))
        if not slots:
            return []
        t0 = time.perf_counter()
        W = self._draft_W

        # 1) per-slot window: the finish rule caps pos at cache_len-1, so
        # lookahead never needs rows past cache_len-2; clamp to what the
        # target pool covers after extension.  extend() is all-or-nothing,
        # and the admission-time baseline reservation already covers
        # pos+1 rows for any active slot — under pool pressure k degrades
        # toward plain decode instead of deadlocking.
        k_eff: Dict[int, int] = {}
        for s in slots:
            k = max(0, min(self.spec_k,
                           self.cache_len - 2 - int(self.pos[s])))
            if k > 0:
                covered = self.backend.extend(s, int(self.pos[s]) + k + 1)
                k = max(0, min(k, covered - int(self.pos[s]) - 1))
            k_eff[s] = k

        # 2) linear draft chain: step 1 flushes each slot's pending target
        # emissions, steps 2..k feed the previous proposal back
        drafts: Dict[int, List[int]] = {s: [] for s in slots}
        dists: Dict[int, List[np.ndarray]] = {s: [] for s in slots}
        fed: Dict[int, int] = {s: 0 for s in slots}
        cur = {s: int(self._draft_pos[s]) for s in slots}
        feed = {s: list(self._draft_pending[s])
                for s in slots if k_eff[s] > 0}
        t_draft = time.perf_counter()
        kmax = max(k_eff.values(), default=0)
        for j in range(1, kmax + 1):
            if not feed:
                break
            logits = self._draft_forward(feed, cur)
            nxt: Dict[int, List[int]] = {}
            for s, toks in feed.items():
                if j == 1:
                    fed[s] = len(toks)
                cur[s] += len(toks)
                row = logits[s]
                if self._temperature > 0:
                    p = SP.softmax(row, self._temperature)
                    d = int(self._spec_rng.choice(p.shape[0], p=p))
                    dists[s].append(p)
                else:
                    d = int(np.argmax(row))
                drafts[s].append(d)
                if k_eff[s] > j:
                    nxt[s] = [d]
            feed = nxt
        if self.tracer is not None and kmax:
            self.tracer.span("draft", "engine", self.tracer.rel(t_draft),
                             self.tracer.now(), batch=len(slots), k=kmax)

        # 3) one target pass scores every window: logits row i is the
        # target distribution conditioned on the first i draft tokens
        tokens = np.zeros((self.slots, W), np.int32)
        valid = np.zeros((self.slots,), np.int32)
        offs = np.zeros((self.slots,), np.int32)
        for s in slots:
            win = [int(self.last_tok[s])] + drafts[s]
            tokens[s, :len(win)] = win
            valid[s] = len(win)
            offs[s] = int(self.pos[s])
        batch = {"tokens": jnp.asarray(tokens),
                 "offset": jnp.asarray(offs),
                 "valid": jnp.asarray(valid),
                 "block_tables": self._decode_block_tables()}
        t_ver = time.perf_counter()
        with self._phase(f"verify@{self.spec_k}"):
            logits, self.caches = self.verify_step(
                self.params, batch, self.caches)
            logits = np.asarray(logits)
        rows = int(sum(int(self.pos[s]) + int(valid[s]) for s in slots))
        self.kv_bytes_streamed += rows * self._kv_row_bytes
        self.kv_bytes_streamed_per_device += rows * (
            self._kv_row_bytes // max(self._kv_shards, 1))
        if self.tracer is not None:
            self.tracer.span("verify", "engine", self.tracer.rel(t_ver),
                             self.tracer.now(), batch=len(slots))

        # 4) host accept/reject + emission (finish rules identical to
        # ``_consume``)
        finished: List[Request] = []
        for s in slots:
            req = self.active[s]
            k = k_eff[s]
            rows_l = logits[s, :k + 1]
            if self._temperature > 0:
                tprobs = SP.softmax(rows_l, self._temperature)
                dprobs = (np.stack(dists[s]) if dists[s]
                          else np.zeros((0, rows_l.shape[-1])))
                emitted, a = SP.speculative_sample(
                    tprobs, dprobs, drafts[s], self._spec_rng)
            else:
                emitted, a = SP.greedy_verify(
                    np.argmax(rows_l, axis=-1), drafts[s])
            self.draft_tokens_proposed += k
            self.draft_tokens_accepted += a
            done = False
            for tok in emitted:
                tok = int(tok)
                req.out.append(tok)
                self.tokens_generated += 1
                self.spec_tokens_emitted += 1
                self.last_tok[s] = tok
                self.pos[s] += 1
                if (len(req.out) >= req.max_new_tokens
                        or tok == self.stop_token
                        or self.pos[s] >= self.cache_len - 1):
                    done = True
                    break
            if done:
                finished.append(self._finish(s, req))
                continue
            # draft bookkeeping: the draft cache holds valid rows for the
            # flushed pending tokens and d_1..d_a (d_k's KV was never
            # written); everything past them is overwritten by the next
            # chain, which starts exactly at the new _draft_pos
            x = int(self.last_tok[s])
            if k == 0:
                # only reachable right at the cache horizon (pos >=
                # cache_len-2), where the emission above finishes the slot
                # — kept for safety
                self._draft_pending[s].append(x)
            elif a < k:
                self._draft_pos[s] += fed[s] + a
                self._draft_pending[s] = [x]
            else:
                self._draft_pos[s] += fed[s] + k - 1
                self._draft_pending[s] = [drafts[s][-1], x]
            assert len(self._draft_pending[s]) <= W

        # 5) rewind surviving slots to their baseline reservation: the
        # lookahead tail past prompt_len + max_new holds only rejected or
        # replayable rows (a slot's valid rows never exceed
        # prompt_len + max_new - 1), and tail pages are always private —
        # shared prefix pages sit at the front of the run
        for s in slots:
            req = self.active[s]
            if req is None:
                continue
            freed = self.backend.rollback(
                s, req.prompt_len + req.max_new_tokens)
            if freed:
                self.rollback_pages += freed
                if self.tracer is not None:
                    self.tracer.instant("rollback", s, rid=req.rid,
                                        pages=freed)
        self.verify_passes += 1
        self.spec_slot_passes += len(slots)
        self.decode_steps += 1
        self.decode_s += time.perf_counter() - t0
        return finished

    def step(self) -> Optional[List[Request]]:
        """One engine cycle: admit, (chunked: run prefill slabs,) then
        decode every generating slot.

        With ``async_dispatch`` (the default) the decode step submitted in
        cycle N is consumed at the START of cycle N+1, so the host's
        admission / prefix-index / allocator work overlaps the in-flight
        device step.  Token streams are identical to the synchronous
        engine; a request's finish surfaces one cycle later.

        Returns the requests that finished this cycle, or ``None`` when the
        engine is idle (nothing active after admission).
        """
        finished: List[Request] = []
        if self.chunked:
            if self._inflight is not None:
                # overlap host-side admission with the in-flight decode; a
                # deferral here is retried (and counted) after consume
                self._admit_chunked(count_defer=False)
            finished.extend(self._consume())
            self._admit_chunked()
            chunk_finished: List[Request] = []
            for _ in range(self.chunks_per_step):
                if not self._prefilling:
                    break
                chunk_finished.extend(self._chunk_one())
            # a finish above may unblock a deferred reservation: admit
            # again so freed pages go back to work within the same cycle
            if chunk_finished:
                self._admit_chunked()
            finished.extend(chunk_finished)
        else:
            finished.extend(self._consume())
            finished.extend(self._admit())
        if not self._decoding:
            if (self.chunked and self._prefilling) or finished:
                self.steps += 1
                return finished
            return None
        if self.spec_k:
            finished.extend(self._spec_cycle())
        else:
            self._submit_decode()
            if not self._async:
                finished.extend(self._consume())
        self.steps += 1
        return finished

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        """Run until queue + slots are empty (or ``max_steps`` decode steps
        have run *in this call* — a long-lived engine keeps serving across
        calls); returns every request that finished during the run."""
        finished: List[Request] = []
        start = self.steps
        while (self.queue or any(r is not None
                                 for r in self.active.values())):
            if self.steps - start >= max_steps:
                break
            out = self.step()
            if out is None:
                break
            finished.extend(out)
        return finished

    # ------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, Any]:
        """Engine throughput/latency counters + backend occupancy.

        Per-request latency aggregates (``ttft_*``, ``decode_tok_s_mean``)
        cover requests finished so far — the inputs ``benchmarks/ci_gate``
        and ``serve_bench`` gate on, not just aggregate steps/s."""
        m = {
            "engine_cycles": self.steps,
            "decode_steps": self.decode_steps,
            "tokens_generated": self.tokens_generated,
            "requests_admitted": self.requests_admitted,
            "requests_finished": self.requests_finished,
            "prefill_calls": self.prefill_calls,
            "prefill_traces": self.prefill_traces,
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "decode_steps_per_s": (self.decode_steps / self.decode_s
                                   if self.decode_s else 0.0),
            "tokens_per_s": (self.tokens_generated
                             / (self.decode_s + self.prefill_s)
                             if self.decode_s + self.prefill_s else 0.0),
            "deferrals": self.deferrals,
            "tp": self.tp,
            "kv_shards": self._kv_shards,
            "async_dispatch": self._async,
            "kv_bytes_streamed": self.kv_bytes_streamed,
            "kv_bytes_streamed_per_device": self.kv_bytes_streamed_per_device,
            "host_overlap_s": self.host_overlap_s,
            "stream_wait_s": self.stream_wait_s,
            "dispatch_overlap_fraction": (
                self.host_overlap_s
                / (self.host_overlap_s + self.stream_wait_s)
                if self.host_overlap_s + self.stream_wait_s > 0 else 0.0),
            "ttft_s_mean": (float(np.mean(self._ttfts))
                            if self._ttfts else 0.0),
            "ttft_s_p50": (float(np.percentile(self._ttfts, 50))
                           if self._ttfts else 0.0),
            "ttft_s_p95": (float(np.percentile(self._ttfts, 95))
                           if self._ttfts else 0.0),
            "decode_tok_s_mean": (float(np.mean(self._decode_rates))
                                  if self._decode_rates else 0.0),
            "decode_tok_s_p95": (float(np.percentile(self._decode_rates, 95))
                                 if self._decode_rates else 0.0),
        }
        if self.chunked:
            m.update({
                "chunked_prefill": True,
                "chunk_size": self.chunk_size,
                "chunk_calls": self.chunk_calls,
                "chunk_utilization": (
                    self.chunk_tokens / (self.chunk_calls * self.chunk_size)
                    if self.chunk_calls else 0.0),
                "prefix_hit_rate": (self.shared_tokens / self.prefill_tokens
                                    if self.prefill_tokens else 0.0),
            })
        if self.spec_k:
            m.update({
                "speculate_k": self.spec_k,
                "draft_steps": self.draft_steps,
                "verify_passes": self.verify_passes,
                "draft_tokens_proposed": self.draft_tokens_proposed,
                "draft_tokens_accepted": self.draft_tokens_accepted,
                "acceptance_rate": (
                    self.draft_tokens_accepted / self.draft_tokens_proposed
                    if self.draft_tokens_proposed else 0.0),
                # per-SLOT passes, so batching cannot inflate it: 1.0 at
                # zero acceptance, k+1 at full acceptance — the (k+1)x
                # useful-work-per-weight-byte factor of the roofline story
                "tokens_per_target_pass": (
                    self.spec_tokens_emitted / self.spec_slot_passes
                    if self.spec_slot_passes else 0.0),
                "rollback_pages": self.rollback_pages,
            })
        m.update(self.backend.stats())
        return m

    def reset_metrics(self):
        """Zero the metrics window (counters, timers, latency samples) so a
        long-lived engine can report per-interval numbers.  Does NOT touch
        scheduling state: ``steps`` keeps counting (in-flight ``*_step``
        deltas stay valid) and ``_admission_seq`` — the sampling-nonce
        source — never resets, so a slot reused after a reset cannot replay
        a predecessor's randomness."""
        self.tokens_generated = 0
        self.requests_admitted = 0
        self.requests_finished = 0
        self.deferrals = 0
        self.prefill_calls = 0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.decode_steps = 0
        self.chunk_calls = 0
        self.chunk_tokens = 0
        self.prefill_tokens = 0
        self.shared_tokens = 0
        self.kv_bytes_streamed = 0
        self.kv_bytes_streamed_per_device = 0
        self.host_overlap_s = 0.0
        self.stream_wait_s = 0.0
        self.draft_steps = 0
        self.verify_passes = 0
        self.draft_tokens_proposed = 0
        self.draft_tokens_accepted = 0
        self.spec_tokens_emitted = 0
        self.spec_slot_passes = 0
        self.rollback_pages = 0
        self._ttfts.clear()
        self._decode_rates.clear()
