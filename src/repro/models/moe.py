"""Mixture-of-Experts with group-local capacity dispatch (gather-based).

Dispatch uses integer gathers/scatters (bytes, not FLOPs) instead of the
GShard one-hot einsum, so the compiled HLO FLOPs reflect *active* expert
compute — which is what the roofline analysis must see.  Tokens are routed
within ``num_groups`` routing groups; aligning groups with the ``data`` mesh
axis keeps all routing index math shard-local, and only the expert einsum
(experts sharded over ``model``) generates collectives.

Overflowing tokens beyond ``capacity_factor`` contribute zero (standard
capacity-based MoE semantics); the aux load-balancing loss discourages this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import partitioning as PT
from repro.models import modules as M


def moe_init(key, cfg):
    mo, d = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 6)
    mult_gate = cfg.act == "swiglu"
    p = {
        "router": M.dense_init(ks[0], d, mo.num_experts, ("embed", None)),
        "wi_up": _experts_init(ks[1], mo.num_experts, d, mo.d_ff,
                               ("expert", "embed", "expert_ff")),
        "wo": _experts_init(ks[3], mo.num_experts, mo.d_ff, d,
                            ("expert", "expert_ff", "embed")),
    }
    if mult_gate:
        p["wi_gate"] = _experts_init(ks[2], mo.num_experts, d, mo.d_ff,
                                     ("expert", "embed", "expert_ff"))
    if mo.shared_d_ff:
        p["shared"] = M.mlp_init(ks[4], d, mo.shared_d_ff, cfg.act)
        if mo.shared_expert_gate:
            p["shared_gate"] = M.dense_init(ks[5], d, 1, ("embed", None))
    return p


def _expert_stack(w, dtype):
    """Expert-stack view for the gather-path einsums: MX-quantized stacks
    dequantize in-graph (prefill / batched decode — the grouped kernel only
    serves the single-token routed path)."""
    if isinstance(w, M.QuantizedTensor):
        return w.dequantize(dtype)
    return w.astype(dtype)


def _experts_init(key, e, din, dout, axes):
    scale = 1.0 / jnp.sqrt(din).astype(jnp.float32)
    w = scale * jax.random.truncated_normal(
        key, -2.0, 2.0, (e, din, dout), jnp.float32)
    return {"w": M.Param(w, axes)}


def _batch_specs(G):
    """shard_map specs for group-local index ops (G sharded over batch)."""
    from jax.sharding import PartitionSpec as P
    b_ax = PT.resolve("batch")
    if b_ax is None or G % max(PT.mesh_size(b_ax), 1) or \
            PT.mesh_size(b_ax) <= 1:
        b_ax = None
    return b_ax


def _local_gather(xf, idx):
    """(G,n,d),(G,S) -> (G,S,d), shard-local over the batch axes (C6)."""
    def local(x, i):
        return jnp.take_along_axis(x, i[..., None], axis=1)
    if not PT.active():
        return local(xf, idx)
    b_ax = _batch_specs(xf.shape[0])
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    return shard_map(local, mesh=PT._CTX.mesh,
                     in_specs=(P(b_ax, None, None), P(b_ax, None)),
                     out_specs=P(b_ax, None, None),
                     check_rep=False)(xf, idx)


def _local_combine(yw, idx, n):
    """Scatter-add (G,S,d) slot rows back to (G,n,d), shard-local (C6)."""
    def local(y, i):
        G_l = y.shape[0]
        return jnp.zeros((G_l, n, y.shape[-1]), y.dtype).at[
            jnp.arange(G_l)[:, None], i].add(y)
    if not PT.active():
        return local(yw, idx)
    b_ax = _batch_specs(yw.shape[0])
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    return shard_map(local, mesh=PT._CTX.mesh,
                     in_specs=(P(b_ax, None, None), P(b_ax, None)),
                     out_specs=P(b_ax, None, None),
                     check_rep=False)(yw, idx)


def _route_group(xg, logits, mo, capacity):
    """Single routing group. xg: (n, d) logits: (n, E) -> dispatch plan."""
    n, E = logits.shape
    k = mo.num_experts_per_tok
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (n, k)
    if mo.norm_topk_prob:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    top_p = top_p * mo.routed_scaling_factor

    flat_e = top_e.reshape(-1)                                 # (n*k,)
    flat_p = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    # position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (n*k, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1    # (n*k,)
    keep = pos < capacity
    # token id occupying (expert, slot); 'drop' mode discards overflow
    dispatch = jnp.zeros((E, capacity), jnp.int32).at[
        flat_e, jnp.where(keep, pos, capacity)].set(flat_t, mode="drop")
    valid = jnp.zeros((E, capacity), jnp.float32).at[
        flat_e, jnp.where(keep, pos, capacity)].set(1.0, mode="drop")
    gates = jnp.zeros((E, capacity), jnp.float32).at[
        flat_e, jnp.where(keep, pos, capacity)].set(flat_p, mode="drop")
    # aux loss terms (load balancing, Switch-style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return dispatch, valid, gates, aux


def _apply_moe_routed(p, cfg, x, *, dtype):
    """Single-token MoE through the registry gemv kernels — the kernel-
    routing capture mode behind ``obs.profiler.audit_decode_step``.  Same
    math as the gather path at B*T == 1 (fp32 router, top-k with optional
    prob renormalization and scaling, k routed expert MLPs, shared
    expert + sigmoid gate); the aux loss is zero (decode discards it)."""
    from repro.kernels import ops as KO
    mo = cfg.moe
    B, T, d = x.shape
    k = mo.num_experts_per_tok
    logits = M.apply_dense(p["router"], x.reshape(1, d), jnp.float32)
    probs = jax.nn.softmax(logits.reshape(-1), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    if mo.norm_topk_prob:
        top_p = top_p / jnp.sum(top_p)
    top_p = top_p * mo.routed_scaling_factor

    xv = x.reshape(d).astype(dtype)
    if isinstance(p["wi_up"]["w"], M.QuantizedTensor):
        # MX expert stacks: one grouped kernel call per projection — the
        # router's top-k ids are scalar-prefetched and drive the BlockSpec
        # index map, so only the selected experts' fp4/fp8 tiles + E8M0
        # scales are ever DMA'd (DESIGN.md §11)
        ids = top_e.astype(jnp.int32)
        xs = jnp.broadcast_to(xv, (k, d))
        wu = p["wi_up"]["w"]
        up = KO.grouped_expert_qgemv(wu.values, wu.scales, xs, ids)
        if "wi_gate" in p:
            wg = p["wi_gate"]["w"]
            gate = KO.grouped_expert_qgemv(wg.values, wg.scales, xs, ids)
            h = jax.nn.silu(gate) * up                   # (k, d_ff)
        else:
            h = M.activation(cfg.act)(up)
        wo = p["wo"]["w"]
        yk = KO.grouped_expert_qgemv(wo.values, wo.scales,
                                     h.astype(dtype), ids)   # (k, d)
        y = jnp.sum(top_p[:, None] * yk, axis=0).astype(dtype)
    else:
        y = jnp.zeros((d,), dtype)
        for j in range(k):
            e = top_e[j]
            up_w = jax.lax.dynamic_index_in_dim(
                p["wi_up"]["w"], e, keepdims=False)      # (d, d_ff)
            up = KO.gemv(up_w.T.astype(dtype), xv)
            if "wi_gate" in p:
                gate_w = jax.lax.dynamic_index_in_dim(
                    p["wi_gate"]["w"], e, keepdims=False)
                h = jax.nn.silu(KO.gemv(gate_w.T.astype(dtype), xv)) * up
            else:
                h = M.activation(cfg.act)(up)
            wo_w = jax.lax.dynamic_index_in_dim(
                p["wo"]["w"], e, keepdims=False)         # (d_ff, d)
            yj = KO.gemv(wo_w.T.astype(dtype), h.astype(dtype))
            y = y + top_p[j].astype(dtype) * yj.astype(dtype)
    y = y.reshape(B, T, d)
    if "shared" in p:
        ys = M.apply_mlp(p["shared"], x, cfg.act, dtype)
        if "shared_gate" in p:
            ys = ys * jax.nn.sigmoid(
                M.apply_dense(p["shared_gate"], x, dtype))
        y = y + ys
    return y, jnp.zeros((), jnp.float32)


def apply_moe(p, cfg, x, *, dtype, num_groups: int = 1):
    """x: (B, T, d) -> (B, T, d), aux-loss scalar."""
    mo = cfg.moe
    B, T, d = x.shape
    wu = p["wi_up"]["w"]
    mx_experts = isinstance(wu, M.QuantizedTensor) and wu.fmt == "mx"
    if M.kernel_routed() and B * T == 1 and M._no_tp() \
            and (mx_experts or not isinstance(wu, M.QuantizedTensor)):
        return _apply_moe_routed(p, cfg, x, dtype=dtype)
    N = B * T
    G = num_groups
    while N % G:
        G -= 1
    n = N // G
    E, k = mo.num_experts, mo.num_experts_per_tok
    capacity = max(int(n * k / E * mo.capacity_factor + 0.5), k)
    xf = PT.constrain(x.reshape(G, n, d), ("batch", None, None))

    # fp32 router: bf16 logits quantize at ~2^-8 and flip near-tie top-k
    # picks between the batched and the token-by-token decode paths
    logits = M.apply_dense(p["router"], xf, jnp.float32)       # (G, n, E)
    dispatch, valid, gates, aux = jax.vmap(
        lambda xg, lg: _route_group(xg, lg, mo, capacity))(xf, logits)

    # gather tokens into per-expert buffers: (G, E, C, d).  §Perf C6: the
    # gather/scatter are group-local by construction (indices never cross a
    # routing group), but GSPMD cannot prove it and falls back to fp32
    # full-token all-gathers + all-reduces (~25 GB/chip/layer on
    # deepseek-v2-lite train_4k — measured).  shard_map pins them local.
    xe = _local_gather(xf, dispatch.reshape(G, E * capacity))
    xe = xe.reshape(G, E, capacity, d) * valid[..., None].astype(dtype)

    # expert compute (E sharded over "model" => all-to-all here)
    xe = PT.constrain(xe, ("batch", "expert", None, None))
    from repro.dist import tp as _tp
    ctx = _tp.current()
    if ctx is not None:
        # serving TP (expert-parallel): the expert stacks arrive dim-0
        # sharded under shard_map, so slice our contiguous expert block of
        # the replicated dispatch buffer, run the local einsums, and
        # re-concatenate partials in device (= expert-major) order below —
        # the downstream gate/combine then matches tp=1 bitwise
        El = p["wi_up"]["w"].shape[0]
        xe = jax.lax.dynamic_slice_in_dim(
            xe, _tp.axis_index() * El, El, axis=1)
    up = jnp.einsum("gecd,edf->gecf", xe, _expert_stack(p["wi_up"]["w"],
                                                        dtype))
    if "wi_gate" in p:
        gate = jnp.einsum("gecd,edf->gecf", xe,
                          _expert_stack(p["wi_gate"]["w"], dtype))
        h = jax.nn.silu(gate) * up
    else:
        h = M.activation(cfg.act)(up)
    h = PT.constrain(h, ("batch", "expert", None, "expert_ff"))
    ye = jnp.einsum("gecf,efd->gecd", h, _expert_stack(p["wo"]["w"], dtype))
    if ctx is not None:
        ye = jax.lax.all_gather(ye, ctx.axis, axis=1, tiled=True)
    ye = PT.constrain(ye, ("batch", "expert", None, None))
    ye = ye * gates[..., None].astype(dtype)

    # combine: scatter-add back to token order.  §Perf C4: the scatter's
    # output sharding must be pinned — unconstrained, GSPMD replicates the
    # (G,n,d) result and all-reduces ~5 full-token fp32 tensors per MoE
    # layer (measured on deepseek-v2-lite train_4k; see EXPERIMENTS.md).
    y = _local_combine(
        ye.reshape(G, E * capacity, d)
        * valid.reshape(G, -1, 1).astype(dtype),
        dispatch.reshape(G, E * capacity), n)
    y = PT.constrain(y, ("batch", None, None)).reshape(B, T, d)

    if "shared" in p:
        ys = M.apply_mlp(p["shared"], x, cfg.act, dtype)
        if "shared_gate" in p:
            ys = ys * jax.nn.sigmoid(
                M.apply_dense(p["shared_gate"], x, dtype))
        y = y + ys
    return y, jnp.mean(aux) * mo.router_aux_loss_coef
