"""Serving steps: prefill_step / serve_step (single-token decode).

serve_step is the paper's workload: one new token against a KV cache — every
matmul a GEMV-class memory-bound op.  Greedy sampling keeps the step a pure
function (temperature sampling threads an rng key).

``tuned_kernel_configs`` resolves the best-known TroopConfigs for the decode
hot kernels at the serving shapes (from the persistent tune cache, heuristic
defaults when untuned) so the serving layer and kernel-backed model paths
read tuned configs from one place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tuned_kernel_configs(model_cfg, batch_size: int, max_seq: int,
                         dtype=jnp.bfloat16):
    """TroopConfigs for the decode-path kernels at the serving shapes.

    Pure shape-level lookup (ShapeDtypeStruct placeholders — nothing is
    allocated or traced): decode attention over the KV cache and the
    GEMV-class readout projection.
    """
    import repro.kernels  # noqa: F401  (populates the tune registry)
    from repro.tune import get_tuned

    sds = jax.ShapeDtypeStruct
    B, S = batch_size, max_seq
    KV, hd, H = (model_cfg.num_kv_heads, model_cfg.head_dim,
                 model_cfg.num_heads)
    d, V = model_cfg.d_model, model_cfg.vocab_size
    return {
        "decode_attention": get_tuned(
            "decode_attention",
            sds((B, H, hd), dtype), sds((B, S, KV, hd), dtype),
            sds((B, S, KV, hd), dtype), sds((B,), jnp.int32)),
        "gemv": get_tuned("gemv", sds((V, d), dtype), sds((d,), dtype)),
        "rmsnorm": get_tuned("rmsnorm", sds((B, d), dtype),
                             sds((d,), jnp.float32)),
    }


def make_prefill_step(model):
    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch)
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return prefill_step


def make_serve_step(model, *, temperature: float = 0.0,
                    troop_configs=None):
    """``troop_configs`` (from ``tuned_kernel_configs``) is attached to the
    returned step for kernel-backed decode paths and introspection."""
    def serve_step(params, batch, caches):
        logits, caches = model.decode_step(params, batch, caches)
        if temperature > 0:
            key = jax.random.fold_in(jax.random.PRNGKey(0), batch["pos"][0])
            next_tok = jax.random.categorical(
                key, logits[:, -1, :] / temperature)[:, None].astype(jnp.int32)
        else:
            next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, caches
    serve_step.troop_configs = troop_configs
    return serve_step
