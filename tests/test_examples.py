"""The examples must stay runnable (subprocess smoke)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, env=env, timeout=timeout, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_quickstart():
    out = run_example(["examples/quickstart.py", "--steps", "6"])
    assert "served 3 requests" in out


def test_paper_figures():
    out = run_example(["examples/paper_figures.py"])
    assert "Fig. 5" in out and "TROOP" in out


def test_train_lm_short(tmp_path):
    out = run_example(["examples/train_lm.py", "--steps", "8", "--dim", "64",
                       "--layers", "2", "--seq", "32", "--batch", "2",
                       "--ckpt-dir", str(tmp_path)])
    assert "final loss" in out
