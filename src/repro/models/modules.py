"""Parameter system + basic layers (pure functional JAX).

Every parameter is created inside a ``Param`` box that carries its *logical
sharding axes* (t5x-style).  ``unbox``/``axes_of`` split a boxed tree into the
raw array tree used by apply functions and the logical-axes tree used by the
launcher to derive ``NamedSharding``s.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name as _checkpoint_name
import numpy as np

from repro.quant.tensor import QuantizedTensor


# --------------------------------------------------------------------------
# Param boxing
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Param:
    value: Any                       # jnp array (or ShapeDtypeStruct)
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = replicated)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_param(x) -> bool:
    return isinstance(x, Param)


def unbox(tree):
    return jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)


def axes_of(tree):
    return jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)


def box_like(values, boxed):
    """Re-attach axes from ``boxed`` onto a raw value tree."""
    return jax.tree.map(lambda v, p: Param(v, p.axes), values, boxed,
                        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


# --------------------------------------------------------------------------
# Kernel routing (observability)
# --------------------------------------------------------------------------
# When enabled, single-token projections, rmsnorms and the tied read-out
# dispatch their registry Pallas kernels (repro.kernels.ops) instead of the
# inline jnp expressions — the capture mode behind the dispatch audit
# (obs.profiler.audit_decode_step), which replays a decode step under
# jax.eval_shape and compares the dispatched kernel multiset against
# obs.energy.decode_step_account.  Off by default; the flag is read at
# trace time, so already-jitted steps are unaffected by a later flip.
_KERNEL_ROUTED = False


def kernel_routed() -> bool:
    return _KERNEL_ROUTED


@contextlib.contextmanager
def kernel_routing(enable: bool = True):
    global _KERNEL_ROUTED
    prev = _KERNEL_ROUTED
    _KERNEL_ROUTED = enable
    try:
        yield
    finally:
        _KERNEL_ROUTED = prev


def _no_tp() -> bool:
    from repro.dist import tp as _tp
    return _tp.current() is None


def _gemv_routable(x, w) -> bool:
    """One output row-vector against a raw 2-D weight, outside TP."""
    return (getattr(w, "ndim", 0) == 2 and x.ndim >= 1
            and x.shape[-1] == w.shape[0]
            and int(np.prod(x.shape[:-1], dtype=np.int64)) == 1 and _no_tp())


def _mx_routable(x, w) -> bool:
    """Single-token projection against a 2-D MX-quantized weight whose
    shared-exponent blocks run down the contraction axis — the layout
    ``mx_qgemv`` walks without a transpose."""
    return (isinstance(w, QuantizedTensor) and w.fmt == "mx"
            and len(w.shape) == 2 and w.axis == -2 and x.ndim >= 1
            and x.shape[-1] == w.shape[0]
            and int(np.prod(x.shape[:-1], dtype=np.int64)) == 1 and _no_tp())


def _routed_gemv(w_nk, x, dtype):
    """Dispatch the registry gemv on an (N, K) weight; returns (N,)."""
    from repro.kernels import ops as KO
    return KO.gemv(w_nk, x.reshape(-1)).astype(dtype)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------
def _normal(key, shape, dtype, scale):
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def dense_init(key, in_dim: int, out_dim: int, axes, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None):
    """W: (in_dim, out_dim) with fan-in scaling."""
    scale = (1.0 / np.sqrt(in_dim)) if scale is None else scale
    p = {"w": Param(_normal(key, (in_dim, out_dim), dtype, scale), axes)}
    if bias:
        p["b"] = Param(jnp.zeros((out_dim,), dtype), (axes[1],))
    return p


def apply_dense(p, x, dtype=None, tp=None):
    """``tp``: the projection's tensor-parallel role when serving under a
    TP mesh — ``"col"`` (output columns sharded: qkv/up/gate) or ``"row"``
    (contraction rows sharded: o/down projections).  Outside a TP context
    the flag is inert.  In *exact* TP mode the flag is also inert here:
    column shards are plain local matmuls on the pre-sharded weight and
    row projections see the re-gathered full activation.  In *overlap*
    mode the projection routes through ``repro.dist.collective_matmul``'s
    ring collectives so the gather/scatter hides behind the GEMV."""
    w = p["w"]
    quantized = isinstance(w, QuantizedTensor)
    if _KERNEL_ROUTED and quantized and _mx_routable(x, w):
        # MX weights stream their fp4/fp8 codes + E8M0 scales straight into
        # the fused block-exponent dequant GEMV (DESIGN.md §11)
        from repro.kernels import ops as KO
        if dtype is not None:
            x = x.astype(dtype)
        y = KO.mx_qgemv(w.values, w.scales,
                        x.reshape(-1)).astype(dtype or x.dtype)
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y.reshape(x.shape[:-1] + (w.shape[-1],))
    if quantized:
        # repro.quant weights (DESIGN.md §5): grouped dequant on the fly —
        # the GSPMD-shardable reference of the fused-dequant qgemv kernels
        # (which stream the int8/int4 bytes + scales; repro.quant.kernels)
        w = w.dequantize(dtype or x.dtype)
    elif dtype is not None:
        w = w.astype(dtype)
    if dtype is not None:
        x = x.astype(dtype)
    if _KERNEL_ROUTED and not quantized and _gemv_routable(x, w):
        # W is stored (in_dim, out_dim); the gemv kernel walks (N, K)
        y = _routed_gemv(w.T, x, jnp.result_type(x.dtype, w.dtype))
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y.reshape(x.shape[:-1] + (w.shape[1],))
    if tp is not None:
        from repro.dist import tp as _tp
        ctx = _tp.current()
        if ctx is not None and ctx.mode == "overlap":
            from repro.dist.collective_matmul import (allgather_matmul,
                                                      reduce_scatter_matmul)
            if tp == "col":
                # slice our K-chunk of the replicated activation and walk
                # the ring against the full-K local-column weight
                Kl = x.shape[-1] // ctx.size
                xs = jax.lax.dynamic_slice_in_dim(
                    x, _tp.axis_index() * Kl, Kl, axis=x.ndim - 1)
                y = allgather_matmul(xs, w, ctx.axis)
            else:                                 # "row"
                y = reduce_scatter_matmul(x, w, ctx.axis)
                y = _tp.gather_cols(y)            # re-replicate the tiles
            if "b" in p:
                y = y + p["b"].astype(y.dtype)
            return y
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": Param(_normal(key, (vocab, d), dtype, 1.0),
                           ("vocab", "embed"))}


def apply_embed(p, ids, dtype):
    return jnp.take(p["table"].astype(dtype), ids, axis=0)


def apply_unembed(p, x, dtype):
    """Tied read-out: x @ table.T"""
    t = p["table"].astype(dtype)
    if _KERNEL_ROUTED and x.ndim >= 1 and x.shape[-1] == t.shape[1] \
            and int(np.prod(x.shape[:-1], dtype=np.int64)) == 1 and _no_tp():
        y = _routed_gemv(t, x.astype(dtype), dtype)   # table is (V, d)
        return y.reshape(x.shape[:-1] + (t.shape[0],))
    return x.astype(dtype) @ t.T


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def norm_init(kind: str, d: int, axes=("embed",)):
    p = {"scale": Param(jnp.ones((d,), jnp.float32), axes)}
    if kind == "layernorm":
        p["bias"] = Param(jnp.zeros((d,), jnp.float32), axes)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    if _KERNEL_ROUTED and kind == "rmsnorm" and "bias" not in p and _no_tp():
        from repro.kernels import ops as KO
        d = x.shape[-1]
        # eps is a static kernel arg — must stay a kwarg
        return KO.rmsnorm(x.reshape(-1, d), p["scale"],
                          eps=eps).reshape(x.shape)
    dtype = x.dtype
    x = x.astype(jnp.float32)
    if kind == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        x = x - mu
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    x = x * p["scale"]
    if "bias" in p:
        x = x + p["bias"]
    return x.astype(dtype)


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------
def activation(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "silu":
        return jax.nn.silu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise KeyError(name)


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, act: str, *, ff_axis: str = "ffn",
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {}
    if act == "swiglu":
        p["wi_gate"] = dense_init(ks[0], d, d_ff, ("embed", ff_axis), dtype=dtype)
        p["wi_up"] = dense_init(ks[1], d, d_ff, ("embed", ff_axis), dtype=dtype)
    else:
        p["wi_up"] = dense_init(ks[1], d, d_ff, ("embed", ff_axis), dtype=dtype)
    p["wo"] = dense_init(ks[2], d_ff, d, (ff_axis, "embed"), dtype=dtype)
    return p


def apply_mlp(p, x, act: str, dtype):
    from repro.core.partitioning import constrain
    from repro.dist import tp as _tp
    ffn_axes = ("batch",) + (None,) * (x.ndim - 2) + ("ffn",)
    if "wi_gate" in p and _KERNEL_ROUTED \
            and _mx_routable(x, p["wi_gate"]["w"]) \
            and _mx_routable(x, p["wi_up"]["w"]) \
            and "b" not in p["wi_gate"] and "b" not in p["wi_up"]:
        # fused MX swiglu: gate + up dequant-GEMV and the silu·gate
        # epilogue in ONE kernel pass (DESIGN.md §11)
        from repro.kernels import ops as KO
        wg, wu = p["wi_gate"]["w"], p["wi_up"]["w"]
        xk = x.astype(dtype) if dtype is not None else x
        h = KO.mx_qgemv_swiglu(wg.values, wg.scales, wu.values, wu.scales,
                               xk.reshape(-1)).astype(dtype or x.dtype)
        h = h.reshape(x.shape[:-1] + (wg.shape[-1],))
    elif "wi_gate" in p:
        h = jax.nn.silu(apply_dense(p["wi_gate"], x, dtype, tp="col")) * \
            apply_dense(p["wi_up"], x, dtype, tp="col")
    else:
        h = activation(act)(apply_dense(p["wi_up"], x, dtype, tp="col"))
    h = constrain(h, ffn_axes)
    ctx = _tp.current()
    if ctx is not None and ctx.mode == "exact":
        # exact TP: the silu-gate was elementwise on our ffn columns;
        # re-concatenate the shards (bitwise) for the replicated down-proj
        h = _tp.gather_cols(h)
    out = apply_dense(p["wo"], h, dtype, tp="row")
    # §Perf B3/B4: pin the TP reduction in bf16 + name it for the remat
    # policy (see attention.py)
    out = constrain(out, ("batch",) + (None,) * (x.ndim - 1))
    return _checkpoint_name(out, "tp_out")


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(T: int, d: int, offset=0):
    pos = jnp.arange(T, dtype=jnp.float32) + offset
    inv = 1.0 / (10_000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
