import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, reduced


def test_all_archs_load():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.name == a
        assert cfg.num_layers >= 1 and cfg.d_model >= 128


@pytest.mark.parametrize("arch,lo,hi", [
    ("qwen1.5-0.5b", 0.4e9, 0.8e9),
    ("qwen1.5-32b", 28e9, 36e9),
    ("glm4-9b", 8e9, 11e9),
    ("qwen3-14b", 12e9, 16e9),
    ("internvl2-76b", 65e9, 80e9),
    ("deepseek-v2-lite-16b", 13e9, 18e9),
    ("qwen2-moe-a2.7b", 12e9, 16e9),
    ("jamba-v0.1-52b", 45e9, 58e9),
    ("rwkv6-3b", 2.5e9, 3.6e9),
    ("whisper-base", 0.05e9, 0.11e9),
])
def test_param_counts_match_published(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_active_params_less_than_total_for_moe():
    for a in ("deepseek-v2-lite-16b", "qwen2-moe-a2.7b", "jamba-v0.1-52b"):
        cfg = get_config(a)
        assert cfg.param_count(active_only=True) < 0.45 * cfg.param_count()


def test_cells_and_skips():
    live = cells()
    allc = cells(include_skipped=True)
    assert len(allc) == 40
    assert len(live) == 32           # long_500k only for rwkv6 + jamba
    skipped = [c for c in allc if c[2]]
    assert {a for a, s, _ in skipped} == set(ARCH_IDS) - {"rwkv6-3b",
                                                          "jamba-v0.1-52b"}
    assert all(s == "long_500k" for _, s, _ in skipped)


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["decode_32k"].kind == "decode"


def test_reduced_configs_are_small():
    for a in ARCH_IDS:
        r = reduced(get_config(a))
        assert r.param_count() < 30e6
        assert r.layer_kinds()  # pattern still valid
