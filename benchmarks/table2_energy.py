"""Table II analogue: modeled energy efficiency, baseline vs TROOP.

Energy model over the cycle simulator's outputs:
    E = cycles * P_static + mem_beats * E_beat + fpu_busy * E_fma
with constants fit once to the paper's Spatz_BASELINE dp-fdotp entry
(25.9 DP-GFLOPs/W @ 1 GHz) and held fixed.  The quantity validated is the
*ratio* TROOP/baseline per kernel (the paper's +45%/+26%/+9%/+0%)."""
from __future__ import annotations

from repro.core import perfmodel as PM
# the energy constants live in repro.obs.energy — one set of numbers for
# this table AND the serving-level energy attribution (load_bench)
from repro.obs.energy import E_BEAT, E_FMA, P_STATIC  # noqa: F401
from benchmarks.paper_data import TABLE2


def efficiency(kernel: str, cfg) -> float:
    r = PM.utilization(kernel, cfg, 4096)
    flops = 2 * 4096.0
    if kernel == "gemv":
        flops = 2 * 256.0 * 64.0
    if kernel == "gemm":
        flops = 2 * 4096.0 * 8
    mem_beats = {"dotp": 2, "axpy": 3, "gemv": 1.06, "gemm": 0.14,
                 "fft": 2.0}[kernel] * flops / 2 / 4
    energy_pj = r.cycles * P_STATIC + mem_beats * E_BEAT + \
        r.fpu_busy * E_FMA
    gflops_per_w = flops / energy_pj * 1e3   # pJ @ 1 GHz -> GFLOPs/W
    return gflops_per_w


def run(csv=print):
    names = {"dotp": "dp-fdotp", "axpy": "dp-faxpy", "gemv": "dp-gemv",
             "gemm": "dp-fmatmul"}
    for kernel, pname in names.items():
        base = efficiency(kernel, PM.BASELINE)
        troop = efficiency(kernel, PM.BW2X_TROOP)
        p_base, p_troop = TABLE2[pname]
        csv(f"table2/{pname},{troop:.1f},GFLOPsW base={base:.1f} "
            f"ratio={troop / base:.2f} paper_ratio={p_troop / p_base:.2f}")


if __name__ == "__main__":
    run()
