"""Reproduce the paper's figures from the cycle model (ASCII output).

    PYTHONPATH=src python examples/paper_figures.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import perfmodel as PM
from benchmarks.paper_data import FIG5


def bar(frac, width=40):
    return "#" * int(frac * width)


def main():
    res = PM.figure5(4096)
    print("=== Fig. 5: FPU utilization (VL=4096) — model vs paper ===")
    for kernel, row in res.items():
        print(f"\n{kernel.upper()}")
        for cfg_name, util in row.items():
            paper = FIG5.get(kernel, {}).get(cfg_name)
            ptxt = f"  paper={paper * 100:.0f}%" if paper else ""
            print(f"  {cfg_name:18s} {bar(util):40s} {util * 100:5.1f}%{ptxt}")
    print("\n=== long-vector DOTP (VL=65536) ===")
    for name in ("Spatz_2xBW", "Spatz_2xBW_TROOP"):
        u = PM.utilization("dotp", PM.CONFIGS[name], 65536).fpu_util
        print(f"  {name:18s} {bar(u):40s} {u * 100:5.1f}%")
    print("\n(paper: 70% / 96%)")


if __name__ == "__main__":
    main()
