from repro.serve.scheduler import Request, ServingEngine, splice_cache
from repro.serve.step import (make_prefill_step, make_serve_step,
                              tuned_kernel_configs)

__all__ = ["Request", "ServingEngine", "splice_cache",
           "make_prefill_step", "make_serve_step", "tuned_kernel_configs"]
